"""End-to-end driver: federated fine-tuning of a ~100M-param language
model with HiCS-FL client selection, for a few hundred rounds.

This is the framework-scale regime the paper's O(C) selection is built
for: the selector reads only the LM-head update (here the bias-free ΔW
row-mean surrogate, see ``repro.core.hetero.delta_b_from_head_delta``),
never the 100M-param body.

  PYTHONPATH=src python examples/federated_finetune.py            # ~100M
  PYTHONPATH=src python examples/federated_finetune.py --tiny     # CI-fast

The ~100M config is a 4-layer qwen3-family model (d_model=768,
vocab=32k).  Clients hold synthetic token streams with Dirichlet-skewed
topic mixtures — the LM analogue of label heterogeneity.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import estimate_entropy, head_bias_update, make_selector
from repro.data import make_lm_streams
from repro.models import get_model
from repro.optim import apply_updates, clip_by_global_norm, sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--rounds", type=int, default=0)
    args = ap.parse_args()

    base = get_config("qwen3-8b")
    if args.tiny:
        cfg = base.reduced()
        rounds = args.rounds or 6
        clients, select, seq, seqs = 8, 2, 64, 2
    else:
        cfg = dataclasses.replace(
            base.reduced(), name="qwen3-100m", num_layers=4, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32_768)
        rounds = args.rounds or 200
        clients, select, seq, seqs = 16, 4, 256, 2

    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name}  {n_params/1e6:.1f}M params  "
          f"vocab={cfg.vocab_size}")

    rng = np.random.default_rng(0)
    toks, mixes = make_lm_streams(rng, cfg.vocab_size, seq + 1, clients,
                                  seqs, alphas=(0.05,) * 3 + (5.0,))
    toks = jnp.asarray(toks)
    opt = sgd(0.2)

    @jax.jit
    def local_update(params, client_toks):
        """R=1 epoch over the client's sequences."""
        opt_state = opt.init(params)

        def step(carry, seq_tokens):
            p, s = carry
            batch = {"tokens": seq_tokens[None, :-1],
                     "targets": seq_tokens[None, 1:],
                     "loss_mask": jnp.ones((1, seq_tokens.shape[0] - 1))}
            (loss, _), grads = jax.value_and_grad(
                lambda q: api.loss(q, batch, dtype=jnp.float32),
                has_aux=True)(p)
            grads, _ = clip_by_global_norm(grads, 1.0)
            upd, s = opt.update(grads, s, p)
            return (apply_updates(p, upd), s), loss

        (p, _), losses = jax.lax.scan(step, (params, opt_state),
                                      client_toks)
        return p, losses.mean()

    sel = make_selector("hics", num_clients=clients, num_select=select,
                        total_rounds=rounds, temperature=0.63,
                        normalize=True, gamma0=4.0, seed=0)
    t_start = time.time()
    for t in range(rounds):
        ids = sel.select(t)
        locals_, dbs, losses = [], [], []
        for k in ids:
            pk, loss = local_update(params, toks[k])
            locals_.append(pk)
            dbs.append(np.asarray(head_bias_update(params, pk)))
            losses.append(float(loss))
        params = jax.tree_util.tree_map(
            lambda *xs: jnp.mean(jnp.stack(xs), 0), *locals_)
        sel.update(t, ids, bias_updates=np.stack(dbs))
        if t % max(1, rounds // 20) == 0 or t == rounds - 1:
            ent = sel.estimated_entropies()
            spread = (float(np.ptp(ent)) if ent is not None else 0.0)
            print(f"round {t:4d} loss={np.mean(losses):.4f} "
                  f"sel={sorted(map(int, ids))} Ĥ-spread={spread:.3f} "
                  f"({time.time()-t_start:.0f}s)", flush=True)
    print(f"\ndone: {rounds} rounds in {time.time()-t_start:.0f}s; "
          f"selector overhead {sel.select_seconds + sel.update_seconds:.2f}s"
          f" total (model has {n_params/1e6:.1f}M params the selector "
          "never touches)")


if __name__ == "__main__":
    main()
