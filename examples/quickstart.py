"""Quickstart: HiCS-FL in ~60 seconds on CPU.

Runs a 50-client federated classification experiment (the paper's
FMNIST-style setting (1): 80% of clients severely imbalanced, 20%
balanced) with HiCS-FL selection, then prints the estimated-vs-true
entropy table and the accuracy trajectory vs random sampling — and a
short tour of the selector API's two faces (the OO shim and the
functional ``(init, select, update)`` protocol).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (Observations, label_entropy, make_functional,
                        make_selector)
from repro.data import SyntheticSpec
from repro.fed import (ExperimentSpec, LocalSpec, build,
                       rounds_to_accuracy)

import jax
import jax.numpy as jnp

ROUNDS = 40


def run(selector, selector_kw=None, seed=0):
    spec = ExperimentSpec(
        arch="paper-mlp", num_clients=50, num_select=5, rounds=ROUNDS,
        alphas=(0.001, 0.002, 0.005, 0.01, 0.5),   # paper setting (1)
        selector=selector, selector_kw=selector_kw,
        data=SyntheticSpec(noise=0.5, proto_scale=1.2),
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.05,
                        epochs=2, batch_size=32),
        samples_train=10_000, samples_test=2_000, eval_every=5,
        seed=seed)
    server, info = build(spec)
    hist = server.run()
    return server, info, hist


def main():
    print("=== HiCS-FL quickstart: setting (1), 50 clients, K=5 ===\n")
    server, info, hist = run(
        "hics", {"temperature": 0.63, "gamma0": 4.0, "normalize": True})

    # estimated vs true heterogeneity (the paper's core estimator)
    ent_hat = server.selector.estimated_entropies()
    ent_true = np.asarray(label_entropy(jnp.asarray(info["label_dists"])))
    corr = np.corrcoef(ent_hat, ent_true)[0, 1]
    print(f"Ĥ(softmax(Δb/T)) vs H(D): Pearson r = {corr:.3f}")
    order = np.argsort(-ent_hat)[:8]
    print("  top-8 estimated-entropy clients "
          f"(α of each): {[info['client_alpha'][i] for i in order]}")
    print("   -> the balanced (α=0.5) clients float to the top\n")

    print("accuracy trajectory (HiCS-FL):",
          [round(a, 3) for a in hist["test_acc"]])
    _, _, hist_rand = run("random")
    print("accuracy trajectory (random) :",
          [round(a, 3) for a in hist_rand["test_acc"]])
    for target in (0.4, 0.5):
        rh = rounds_to_accuracy(hist, target)
        rr = rounds_to_accuracy(hist_rand, target)
        if rh and rr:
            print(f"rounds to {target:.0%}: HiCS-FL {rh} vs random {rr} "
                  f"({rr/rh:.1f}x speedup)")
    print(f"\nselection overhead: {server.selector.select_seconds*1e3:.1f} ms"
          f" total across {ROUNDS} rounds (O(C) server-side)")

    selector_api_tour()
    incremental_selection_tour()
    scenario_sweep_tour()


def selector_api_tour():
    """The selector API's two faces, on fake Δb observations.

    1. The OO *shim* — the historical stateful interface.  Internally a
       thin wrapper over the functional core; legacy keyword updates
       still work.
    2. The *functional protocol* — a pure ``(init, select, update)``
       triple over an explicit ``SelectorState`` pytree.  Because both
       transitions are pure and jit-compatible, ``FederatedServer``
       can scan entire rounds (``jit_rounds=True`` /
       ``ExperimentSpec(jit_rounds=True)``) with zero host transfers
       between select and update, and sweeps vmap over stacked states.
    """
    print("\n=== selector API tour (N=12 clients, K=3) ===")
    n, k, rounds = 12, 3, 10
    dbs = np.random.default_rng(0).normal(0.0, 0.02, (n, 10))

    # -- 1. the OO shim ---------------------------------------------------
    sel = make_selector("hics", num_clients=n, num_select=k,
                        total_rounds=rounds, temperature=0.0025, seed=7)
    for t in range(5):
        ids = sel.select(t)
        sel.update(t, ids, bias_updates=dbs[ids])      # legacy kwargs
    print("shim       :", ids, "<- sel.select(t) / sel.update(t, ids, ...)")

    # -- 2. the functional protocol --------------------------------------
    fn = make_functional("hics", num_clients=n, num_select=k,
                         total_rounds=rounds, temperature=0.0025,
                         num_classes=10)
    state = fn.init(jax.random.PRNGKey(7))
    key = jax.random.PRNGKey(0)
    for t in range(5):
        key, kt = jax.random.split(key)
        ids, state = fn.select(state, t, kt)           # pure, jittable
        state = fn.update(state, t, ids,
                          Observations(bias_updates=jnp.asarray(dbs)[ids]))
    print("functional :", [int(i) for i in ids],
          "<- ids, state = fn.select(state, t, key)")
    print("state pytree leaves:",
          [tuple(l.shape) for l in jax.tree_util.tree_leaves(state)][:5],
          "...")


def incremental_selection_tour():
    """Incremental selection: cached Gram/distance state, K-row updates.

    Algorithm 1 replaces only the K participants' Δb rows per round, so
    the N−K other rows of the Eq. 9 distance matrix carry over.  The
    HiCS selector caches that matrix (plus per-row [norm, Ĥ] stats and
    the staled ids) inside its ``SelectorState``, and each ``select``
    refreshes just the K×N strip — O(K·N·C) per round instead of the
    from-scratch O(N²·C) — via ``repro.kernels.hics_selection_step_
    cached`` (MXU-tiled Pallas strip kernel on TPU, jitted oracle on
    CPU).  It is ON by default; ``incremental=False`` restores the
    from-scratch step, and tests/test_incremental_selection.py pins the
    two to identical participant sets over 50-round host / scanned /
    vmapped-sweep runs.  Because the cache is ordinary state-pytree
    data, it rides ``lax.scan`` round loops and the sweep engine's seed
    axis for free; ``BENCH_selection.json`` ("incremental_vs_full")
    tracks the measured speedup per PR.
    """
    print("\n=== incremental selection: K-row cache refresh ===")
    n, k, rounds = 12, 3, 8
    dbs = np.random.default_rng(0).normal(0.0, 0.02, (n, 10))
    picks = {}
    for inc in (True, False):
        fn = make_functional("hics", num_clients=n, num_select=k,
                             total_rounds=rounds, num_classes=10,
                             incremental=inc)
        state = fn.init(jax.random.PRNGKey(7))
        key = jax.random.PRNGKey(0)
        out = []
        for t in range(rounds):
            key, kt = jax.random.split(key)
            ids, state = fn.select(state, t, kt)
            out.append([int(i) for i in ids])
            state = fn.update(state, t, ids, Observations(
                bias_updates=jnp.asarray(dbs)[ids]))
        picks[inc] = out
    print("cached (N,N) distance + (N,2) stats ride the state pytree;"
          f" parity with from-scratch: {picks[True] == picks[False]}")


def scenario_sweep_tour():
    """A multi-seed, multi-scenario sweep in 3 lines.

    ``repro.scenarios`` holds device-resident heterogeneity scenarios
    (the paper's §4.1 settings plus shards / quantity-skew / dropout
    regimes) and a sweep engine that vmaps the jitted round loop over a
    stack of seeds — partitions, selector states, and model params all
    batched — so "S seeds × scenario × selector" is one XLA program,
    reproducing the host loop seed-for-seed (tests/test_sweep.py).
    """
    print("\n=== scenario sweep: seeds vmapped, 3 lines ===")
    from repro.data import SyntheticSpec
    from repro.scenarios import SweepSpec, run_sweep

    # the 3 lines (spec / run / read) — sized down for the quickstart:
    spec = SweepSpec(scenarios=("mixed_80_20", "dir_mild"),
                     selectors=("hics", "random"), seeds=(0, 1),
                     num_clients=10, num_select=3, rounds=6,
                     samples_train=400, samples_test=120,
                     data=SyntheticSpec(dim=16, rank=2, noise=0.5),
                     local=LocalSpec(lr=0.1, epochs=1, batch_size=32))
    res = run_sweep(spec)
    print({cell: f"{d['final_acc_mean']:.3f}±{d['final_acc_std']:.3f}"
           for cell, d in res["grid"].items()})


if __name__ == "__main__":
    main()
