"""Batched serving example: prefill + decode with any assigned arch, and
a direct comparison of the decode hot loop against the GQA flash-decode
Pallas kernel (interpret mode on CPU; compiled on TPU).

  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x22b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import gqa_decode_attention
from repro.kernels.ref import decode_attention_ref
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    if cfg.kind == "vlm":
        P = cfg.vlm.num_patches
        batch = {"patches": jnp.asarray(
            rng.normal(size=(B, P, cfg.vlm.patch_embed_dim)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                               (B, S - P)), jnp.int32)}
    elif cfg.kind == "audio":
        F = min(cfg.encdec.max_source_frames, S)
        batch = {"frames": jnp.asarray(rng.normal(size=(B, F, cfg.d_model)),
                                       jnp.float32),
                 "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (B, S)), jnp.int32)}
    else:
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (B, S)), jnp.int32)}

    prefill = jax.jit(make_prefill_step(api, dtype=jnp.float32,
                                        cache_extra=args.gen))
    serve = jax.jit(make_serve_step(api, dtype=jnp.float32),
                    donate_argnums=(1,))
    token, cache = prefill(params, batch)
    token.block_until_ready()
    t0 = time.time()
    toks = [np.asarray(token)]
    for i in range(args.gen - 1):
        token, cache = serve(params, cache,
                             {"token": token,
                              "pos": jnp.asarray(S + i, jnp.int32)})
        toks.append(np.asarray(token))
    token.block_until_ready()
    dt = (time.time() - t0) / max(1, args.gen - 1)
    print(f"{cfg.name}: batch={B} prompt={S} -> {args.gen} tokens, "
          f"{dt*1e3:.1f} ms/token (CPU, reduced config)")
    print("sample:", np.concatenate(toks, 1)[0][:12].tolist())

    # decode-attention kernel vs oracle on this arch's GQA geometry
    if cfg.num_heads:
        H, KV, dh = cfg.num_heads, max(cfg.num_kv_heads, 1), \
            cfg.resolved_head_dim()
        s = 512
        q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, s, KV, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, s, KV, dh)), jnp.float32)
        got = gqa_decode_attention(q, k, v, s, use_pallas=True)
        want = decode_attention_ref(q, k, v, s)
        err = float(jnp.max(jnp.abs(got - want)))
        print(f"flash-decode kernel (H={H} KV={KV} dh={dh} S={s}): "
              f"max|Δ| vs oracle = {err:.2e}")


if __name__ == "__main__":
    main()
