"""Kernel-level benchmark: validates the Pallas kernels at LLM-head
scale (the framework's §3.2 hot spots) and times the CPU oracle paths.

Wall-times here are CPU reference numbers (interpret-mode Pallas is a
correctness tool, not a performance path); the TPU performance story is
the roofline analysis in bench_roofline.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.fused_stats import fused_stats_pallas
from repro.kernels.hetero_entropy import entropy_pallas
from repro.kernels.pairwise import (hics_selection_step_pallas,
                                    pairwise_distance_pallas)


def main(quick: bool = True):
    print("== bench_kernels ==", flush=True)
    rng = np.random.default_rng(0)
    out = {}

    # entropy at vocab scale: N=64 clients x C=32k classes
    n, c = (64, 32_768) if quick else (256, 151_936)
    x = jnp.asarray(rng.normal(size=(n, c)) * 0.01, jnp.float32)
    t0 = time.perf_counter()
    want = ref.entropy_ref(x, 0.0025).block_until_ready()
    t_ref = time.perf_counter() - t0
    got = entropy_pallas(x, 0.0025, interpret=True)
    err = float(jnp.max(jnp.abs(got - want)))
    out["entropy"] = {"n": n, "c": c, "max_err": err,
                      "ref_seconds": t_ref}
    print(f"  entropy N={n} C={c}: ref {t_ref*1e3:.1f} ms, "
          f"kernel-vs-ref err {err:.2e}", flush=True)
    assert err < 1e-3

    # fused single-sweep stats at the same scale: ONE pass replaces the
    # entropy kernel + jnp.linalg.norm + pad copy of the unfused path
    ent_f, norm_f, rms_f = fused_stats_pallas(x, 0.0025, interpret=True)
    want_norm = jnp.linalg.norm(x, axis=-1)
    err_e = float(jnp.max(jnp.abs(ent_f - want)))
    err_n = float(jnp.max(jnp.abs(norm_f - want_norm)))
    err_r = float(jnp.max(jnp.abs(
        rms_f - jnp.sqrt(jnp.mean(jnp.square(x), axis=-1)))))
    out["fused_stats"] = {"n": n, "c": c, "max_err_entropy": err_e,
                          "max_err_norm": err_n, "max_err_rms": err_r,
                          "hbm_sweeps_pre_gram": 1,
                          "unfused_sweeps_pre_gram": 3}
    print(f"  fused-stats N={n} C={c}: entropy err {err_e:.2e}, "
          f"norm err {err_n:.2e}, rms err {err_r:.2e} (1 sweep vs 3)",
          flush=True)
    assert err_e < 1e-3 and err_n < 1e-3 and err_r < 1e-3

    # end-to-end fused selection step vs the stitched oracle
    ent_s, dist_s = hics_selection_step_pallas(x, 0.0025, lam=10.0,
                                               interpret=True)
    want_e, want_d = ref.selection_step_ref(x, 0.0025, 10.0)
    err_se = float(jnp.max(jnp.abs(ent_s - want_e)))
    err_s = float(jnp.max(jnp.abs(dist_s - want_d)))
    out["selection_step"] = {"n": n, "c": c, "max_err": err_s,
                             "max_err_entropy": err_se}
    print(f"  selection-step N={n} C={c}: dist err {err_s:.2e}, "
          f"entropy err {err_se:.2e}", flush=True)
    assert err_s < 5e-3 and err_se < 1e-3

    # pairwise Eq. 9 at the same scale
    h = ref.entropy_ref(x, 0.0025)
    norms = jnp.linalg.norm(x, axis=-1)
    t0 = time.perf_counter()
    want_d = ref.pairwise_distance_ref(x, h, 10.0).block_until_ready()
    t_ref = time.perf_counter() - t0
    got_d = pairwise_distance_pallas(x, norms, h, lam=10.0,
                                     interpret=True)
    errd = float(jnp.max(jnp.abs(got_d - want_d)))
    out["pairwise"] = {"n": n, "c": c, "max_err": errd,
                       "ref_seconds": t_ref}
    print(f"  pairwise N={n} C={c}: ref {t_ref*1e3:.1f} ms, "
          f"err {errd:.2e}", flush=True)
    assert errd < 5e-3

    # decode attention at serving scale (reduced when quick)
    b, hq, kv, dh, s = (2, 8, 2, 64, 4096) if quick \
        else (8, 32, 8, 128, 32_768)
    q = jnp.asarray(rng.normal(size=(b, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    t0 = time.perf_counter()
    want_a = ref.decode_attention_ref(q, k, v, s).block_until_ready()
    t_ref = time.perf_counter() - t0
    got_a = decode_attention_pallas(q, k, v, s, interpret=True)
    erra = float(jnp.max(jnp.abs(got_a - want_a)))
    out["decode_attention"] = {"b": b, "h": hq, "kv": kv, "dh": dh,
                               "s": s, "max_err": erra,
                               "ref_seconds": t_ref}
    print(f"  decode-attn B={b} H={hq} S={s}: ref {t_ref*1e3:.1f} ms, "
          f"err {erra:.2e}", flush=True)
    assert erra < 1e-3

    save_result("kernels", out)
    return out


if __name__ == "__main__":
    main()
