"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

ART = Path(__file__).resolve().parent / "artifacts"


def save_result(name: str, payload: dict) -> Path:
    ART.mkdir(parents=True, exist_ok=True)
    p = ART / f"{name}.json"
    p.write_text(json.dumps(stamp_env(payload), indent=1,
                            default=_np_default))
    return p


def stamp_env(payload: dict) -> dict:
    """Ensure the payload carries an ``env`` stamp (jax version,
    backend/device kind, CPU count, git SHA) so ``tools/bench_gate.py``
    can refuse cross-machine comparisons instead of flagging them as
    regressions.  Every BENCH writer routes through this."""
    if "env" not in payload:
        from repro.telemetry import env_stamp
        payload = dict(payload)
        payload["env"] = env_stamp()
    return payload


def load_result(name: str) -> dict | None:
    p = ART / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def savitzky_golay(y, window: int = 13, order: int = 3) -> np.ndarray:
    """The paper's plotting filter (App. A.1.1), own implementation —
    polynomial least-squares over a sliding window."""
    y = np.asarray(y, dtype=np.float64)
    n = len(y)
    if n < window:
        return y.copy()
    half = window // 2
    # precompute the center-row convolution coefficients
    x = np.arange(-half, half + 1)
    A = np.vander(x, order + 1, increasing=True)
    coeffs = np.linalg.pinv(A)[0]          # evaluates the fit at x=0
    ypad = np.concatenate([y[half:0:-1], y, y[-2:-half - 2:-1]])
    out = np.convolve(ypad, coeffs[::-1], mode="valid")
    return out[:n]


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def md_table(headers, rows) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)
