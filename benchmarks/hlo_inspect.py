import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration profiler: lowers one (arch × shape × mesh) combo and
attributes collective traffic to source ops.

  PYTHONPATH=src python -m benchmarks.hlo_inspect --arch qwen3-8b \
      --shape train_4k [--mesh pod1] [--dump /tmp/x.hlo]

Prints every collective with: execution count (trip-weighted), local
result bytes, weighted wire bytes, and the op_name metadata XLA carries
from jaxpr — which names the model code that produced it.
"""
import argparse
import re
from collections import defaultdict

from repro.roofline.hlo_cost import (_COLLECTIVES, _exec_counts,
                                     _shape_elems_bytes, parse_module)

_METADATA_RE = re.compile(r'op_name="([^"]*)"')


def collect(hlo: str, top: int = 30):
    comps, entry = parse_module(hlo)
    counts = _exec_counts(comps, entry)
    rows = []
    for comp in comps.values():
        c = counts.get(comp.name, 0.0)
        if c == 0.0:
            continue
        for op in comp.ops:
            kind = op.opcode.replace("-start", "")
            if kind not in _COLLECTIVES or op.opcode.endswith("-done"):
                continue
            _, rbytes = _shape_elems_bytes(op.result_type)
            w = c * rbytes * (2.0 if kind == "all-reduce" else 1.0)
            m = _METADATA_RE.search(op.line)
            src = m.group(1) if m else "?"
            rows.append((w, c, rbytes, kind, op.result_type.strip(),
                         src[-110:]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total weighted collective bytes/chip: {total/1e9:.2f} GB")
    print(f"{'GB(wire)':>9} {'count':>6} {'GB(res)':>8} kind  result  source")
    for w, c, rb, kind, rt, src in rows[:top]:
        print(f"{w/1e9:9.3f} {c:6.0f} {rb/1e9:8.4f} {kind:<15s} "
              f"{rt[:34]:<34s} {src}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--dump", default="")
    ap.add_argument("--top", type=int, default=30)
    ap.add_argument("--policy", default="2d", choices=["2d", "fsdp", "ep"])
    ap.add_argument("--cast-bf16", action="store_true")
    args = ap.parse_args()

    from repro.launch import dryrun as dr
    # reuse run_combo's lowering path but keep the HLO text
    import json
    import jax
    rec_holder = {}

    # monkeypatch-free: call the internals directly
    import jax.numpy as jnp
    from repro.configs import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (make_prefill_step, make_serve_step,
                                    make_train_step)
    from repro.models import cache_specs, get_model, input_specs
    from repro.optim import adam
    from repro.sharding import (ShardingPolicy, batch_pspecs, cache_pspecs,
                                param_pspecs, to_shardings, use_policy)

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "pod2"))
    policy = ShardingPolicy(mesh, mode=args.policy)
    api = get_model(cfg)
    batch_sds = input_specs(cfg, shape)
    with mesh, use_policy(policy):
        if shape.mode == "train":
            opt = adam(1e-4)
            state_sds = jax.eval_shape(lambda: {
                "params": api.init(jax.random.PRNGKey(0)),
                "opt": opt.init(jax.eval_shape(
                    lambda: api.init(jax.random.PRNGKey(0)))),
                "step": jnp.zeros((), jnp.int32)})
            state_ps = {"params": param_pspecs(state_sds["params"], policy),
                        "opt": dr._opt_pspecs(state_sds["opt"], policy),
                        "step": jax.sharding.PartitionSpec()}
            state_sh = to_shardings(state_ps, policy)
            batch_sh = to_shardings(batch_pspecs(batch_sds, policy), policy)
            step = make_train_step(api, opt, dtype=jnp.bfloat16,
                                   cast_params_bf16=args.cast_bf16)
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)).lower(state_sds,
                                                         batch_sds)
        elif shape.mode == "prefill":
            params_sds = jax.eval_shape(lambda: api.init(
                jax.random.PRNGKey(0)))
            params_sds = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                if x.dtype == jnp.float32 else x, params_sds)
            params_sh = to_shardings(param_pspecs(params_sds, policy),
                                     policy)
            batch_sh = to_shardings(batch_pspecs(batch_sds, policy),
                                    policy)
            step = make_prefill_step(api, dtype=jnp.bfloat16)
            lowered = jax.jit(step, in_shardings=(params_sh, batch_sh)) \
                .lower(params_sds, batch_sds)
        else:
            params_sds = jax.eval_shape(lambda: api.init(
                jax.random.PRNGKey(0)))
            params_sds = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                if x.dtype == jnp.float32 else x, params_sds)
            cache_sds = dr._sds_tree(cache_specs(cfg, shape))
            params_sh = to_shardings(param_pspecs(params_sds, policy),
                                     policy)
            cache_sh = to_shardings(cache_pspecs(cache_sds, policy),
                                    policy)
            batch_sh = to_shardings(batch_pspecs(batch_sds, policy),
                                    policy)
            step = make_serve_step(api,
                                   long_context=(shape.name == "long_500k"),
                                   dtype=jnp.bfloat16)
            lowered = jax.jit(step, in_shardings=(params_sh, cache_sh,
                                                  batch_sh),
                              out_shardings=(None, cache_sh),
                              donate_argnums=(1,)).lower(
                params_sds, cache_sds, batch_sds)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(hlo)
        print(f"dumped {len(hlo)} chars to {args.dump}")
    collect(hlo, top=args.top)


if __name__ == "__main__":
    main()
