"""Sweep-engine benchmark: vmapped multi-seed execution vs the python
seed loop (and the legacy FederatedServer host loop), written to
``BENCH_sweep.json`` at the repo root — the batched-evaluation
throughput trajectory CI tracks per PR.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import md_table, save_result
from repro.data import SyntheticSpec
from repro.fed import LocalSpec
from repro.scenarios import SweepSpec, bench_sweep

REPO_ROOT = Path(__file__).resolve().parent.parent


def main(quick: bool = True):
    print("== bench_sweep (vmapped seeds vs python seed loop) ==",
          flush=True)
    spec = SweepSpec(
        scenarios=("mixed_80_20", "dir_mild"),
        selectors=("hics", "random"),
        seeds=(0, 1, 2, 3) if quick else tuple(range(8)),
        num_clients=10 if quick else 32, num_select=3,
        rounds=6 if quick else 20,
        samples_train=400 if quick else 2000,
        samples_test=120 if quick else 400,
        data=SyntheticSpec(dim=16, rank=2, noise=0.5),
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                        epochs=1, batch_size=32))
    res = bench_sweep(spec, include_host=quick)
    save_result("sweep_throughput", res)
    from benchmarks.common import stamp_env
    (REPO_ROOT / "BENCH_sweep.json").write_text(
        json.dumps(stamp_env(res), indent=1))
    print(f"  wrote {REPO_ROOT / 'BENCH_sweep.json'}", flush=True)
    rows = [(cell, f"{d['vmapped_s']:.2f}", f"{d['serial_engine_s']:.2f}",
             f"{d['speedup_vs_serial']:.2f}x",
             f"{d.get('host_loop_s', float('nan')):.2f}")
            for cell, d in res["grid"].items()]
    print(md_table(["scenario/selector", "vmapped s", "serial s",
                    "speedup", "host-loop s"], rows))
    return res


if __name__ == "__main__":
    main()
