"""Benchmark harness entrypoint — one module per paper table/figure.

  python -m benchmarks.run            # quick tier (default)
  python -m benchmarks.run --quick    # same, explicit
  python -m benchmarks.run --full     # paper-scale settings
  python -m benchmarks.run --only selectors,overhead

The quick tier's ``overhead`` module also writes the fused-vs-unfused
selection-step numbers to ``BENCH_selection.json`` at the repo root,
and ``selectors`` writes the scanned-vs-host round-loop numbers to
``BENCH_round_loop.json`` (the per-PR perf trajectory; CI uploads both
as artifacts — see .github/workflows/ci.yml).

Modules:
  selectors  — Tables 1 + 2 (final acc, rounds-to-target, speedup) +
               Fig. 3 (loss variance) across 3 heterogeneity settings
  sweep      — vmapped multi-seed sweep vs python seed loop
               (``BENCH_sweep.json``; see repro.scenarios)
  async      — sync vs buffered-async server under straggler/burst
               latency models (``BENCH_async.json``)
  overhead   — Table 3 (selection compute scaling vs |θ| and C)
  estimation — Figs. 5, 8-11 (Ĥ vs H, Assumption 3.1 envelope)
  kernels    — Pallas kernels vs oracles at LLM-head scale
  roofline   — §Roofline report from the multi-pod dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = ("selectors", "sweep", "async", "overhead", "estimation",
           "ablations", "kernels", "roofline")


def main():
    ap = argparse.ArgumentParser()
    tier = ap.add_mutually_exclusive_group()
    tier.add_argument("--full", action="store_true",
                      help="paper-scale rounds/seeds (slow)")
    tier.add_argument("--quick", action="store_true",
                      help="quick tier (the default)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))
    todo = [m for m in MODULES if not only or m in only]
    t_all = time.time()
    failures = []
    for name in todo:
        mod = __import__(f"benchmarks.bench_{name}",
                         fromlist=["main"])
        t0 = time.time()
        try:
            mod.main(quick=not args.full)
        except Exception as e:  # keep going; report at the end
            failures.append((name, repr(e)))
            print(f"!! bench_{name} FAILED: {e!r}", flush=True)
        print(f"-- bench_{name}: {time.time()-t0:.1f}s\n", flush=True)
    print(f"== benchmarks done in {time.time()-t_all:.1f}s; "
          f"{len(todo)-len(failures)}/{len(todo)} modules ok ==")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
