"""Paper Figs. 8-11 + Fig. 5 analogues:

  * estimated entropy Ĥ(softmax(Δb/T)) vs true label entropy, from REAL
    local training with SGD and with Adam (Figs. 8-10)
  * the Assumption 3.1 dissimilarity envelope (Fig. 5 / App. A.2)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.configs import get_config
from repro.core import (estimate_entropy, head_bias_update, label_entropy)
from repro.core.hetero import dissimilarity_envelope
from repro.data import SyntheticSpec, make_classification_data
from repro.fed import LocalSpec, make_local_update
from repro.models.classifier import make_classifier_with_features

C, DIM = 10, 64


def _make_cohort(rng, num_clients, alphas=(0.001, 0.01, 0.1, 0.5, 1.0)):
    groups = np.array_split(np.arange(num_clients), len(alphas))
    dists = np.zeros((num_clients, C))
    for g, a in zip(groups, alphas):
        for k in g:
            dists[k] = rng.dirichlet(np.full(C, a))
    return dists


def _client_data(rng, dist, x, y, samples=150):
    idx = []
    for c in range(C):
        take = int(round(dist[c] * samples))
        if take:
            idx.extend(rng.choice(np.flatnonzero(y == c), take,
                                  replace=True))
    return x[np.asarray(idx)], y[np.asarray(idx)]


def entropy_estimation(rng, optimizer="sgd", num_clients=30,
                       lr=None) -> dict:
    spec = SyntheticSpec(num_classes=C, dim=DIM, rank=4)
    x, y, _ = make_classification_data(rng, spec, 8000)
    dists = _make_cohort(rng, num_clients)
    cfg = get_config("paper-mlp")
    init, apply, feats = make_classifier_with_features(cfg, input_dim=DIM)
    params = init(jax.random.PRNGKey(0))
    lr = (0.01 if optimizer == "adam" else 0.05) if lr is None else lr
    lspec = LocalSpec(algo="fedavg", optimizer=optimizer, lr=lr,
                      epochs=2, batch_size=32)
    lu = jax.jit(make_local_update(apply, lspec, feats))
    dbs = []
    smax = 400
    for i, dist in enumerate(dists):
        cx, cy = _client_data(rng, dist, x, y)
        xp = np.zeros((smax, DIM), np.float32)
        yp = np.zeros(smax, np.int32)
        mp = np.zeros(smax, np.float32)
        n = min(len(cy), smax)
        xp[:n], yp[:n], mp[:n] = cx[:n], cy[:n], 1.0
        pk, _, _ = lu(params, {}, jnp.asarray(xp), jnp.asarray(yp),
                      jnp.asarray(mp), jax.random.PRNGKey(i))
        dbs.append(np.asarray(head_bias_update(params, pk)))
    db = np.stack(dbs)
    h_true = np.asarray(label_entropy(jnp.asarray(dists)))
    out = {"h_true": h_true.tolist()}
    for label, kw in [("paper_T", dict(temperature=0.05)),
                      ("norm_T", dict(temperature=0.63, normalize=True))]:
        h_hat = np.asarray(estimate_entropy(jnp.asarray(db), **kw))
        r1 = np.argsort(np.argsort(h_hat)).astype(float)
        r2 = np.argsort(np.argsort(h_true)).astype(float)
        out[label] = {
            "h_hat": h_hat.tolist(),
            "pearson": float(np.corrcoef(h_hat, h_true)[0, 1]),
            "spearman": float(np.corrcoef(r1, r2)[0, 1]),
        }
    return out


def assumption31(rng, num_clients=40) -> dict:
    """‖∇F_k − ∇F‖² vs H(D_k) + a fitted envelope (Fig. 5)."""
    spec = SyntheticSpec(num_classes=C, dim=DIM, rank=4)
    x, y, _ = make_classification_data(rng, spec, 8000)
    alphas = np.geomspace(0.01, 50, num_clients)
    dists = np.stack([rng.dirichlet(np.full(C, a)) for a in alphas])
    cfg = get_config("paper-mlp")
    init, apply, _ = make_classifier_with_features(cfg, input_dim=DIM)
    params = init(jax.random.PRNGKey(0))

    def grad_of(cx, cy):
        def lf(p):
            logits = apply(p, jnp.asarray(cx))
            logz = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(
                logits, jnp.asarray(cy)[:, None], axis=-1)[..., 0]
            return jnp.mean(logz - tgt)
        g = jax.grad(lf)(params)
        return np.concatenate([np.ravel(t) for t in
                               jax.tree_util.tree_leaves(g)])

    g_true = grad_of(x, y)
    ents, diffs = [], []
    for i, dist in enumerate(dists):
        cx, cy = _client_data(rng, dist, x, y, samples=250)
        diffs.append(float(np.sum((grad_of(cx, cy) - g_true) ** 2)))
        ents.append(float(label_entropy(jnp.asarray(dist))))
    ents, diffs = np.asarray(ents), np.asarray(diffs)
    # fit the κ − ρ e^{β(H − lnC)} envelope covering >= 95%
    best = None
    kappa = float(diffs.max() * 1.05)
    for beta in (0.5, 1.0, 1.5, 2.0, 3.0):
        for rho_frac in (0.3, 0.5, 0.7, 0.9):
            rho = kappa * rho_frac
            env = dissimilarity_envelope(ents, kappa, rho, beta,
                                         num_classes=C)
            cover = float(np.mean(diffs <= env + 1e-12))
            if cover >= 0.95 and (best is None or rho > best["rho"]):
                best = {"kappa": kappa, "rho": rho, "beta": beta,
                        "coverage": cover}
    hi = diffs[np.argsort(ents)[-10:]].mean()
    lo = diffs[np.argsort(ents)[:10]].mean()
    return {"entropies": ents.tolist(), "sq_diffs": diffs.tolist(),
            "envelope": best, "monotone_gap": float(lo - hi)}


def main(quick: bool = True):
    print("== bench_estimation (Figs. 5, 8-11 analogue) ==", flush=True)
    rng = np.random.default_rng(0)
    res = {}
    for opt in ("sgd", "adam"):
        r = entropy_estimation(np.random.default_rng(0), optimizer=opt,
                               num_clients=20 if quick else 40)
        res[f"entropy_{opt}"] = r
        print(f"  {opt}: pearson paper-T={r['paper_T']['pearson']:.3f} "
              f"norm-T={r['norm_T']['pearson']:.3f} "
              f"(spearman {r['norm_T']['spearman']:.3f})", flush=True)
    a = assumption31(rng, num_clients=24 if quick else 48)
    res["assumption31"] = a
    print(f"  Assumption 3.1: low-H mean diff − high-H mean diff = "
          f"{a['monotone_gap']:.4f} (>0 ⇒ envelope slopes down); "
          f"envelope {a['envelope']}", flush=True)
    save_result("fig5_fig8_estimation", res)
    return res


if __name__ == "__main__":
    main()
