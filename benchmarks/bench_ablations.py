"""Ablations over HiCS-FL's hyper-parameters (the knobs App. A.1.2
fixes): λ (distance mixing), T (softmax temperature), γ⁰ (annealing).

  λ  — cluster purity: fraction of balanced clients isolated from
       imbalanced ones at M=2 under the Eq. 9 distance
  T  — corr(Ĥ, H_true) of the estimator across 3 orders of magnitude
  γ⁰ — early-round accuracy of the full federated loop
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import md_table, save_result
from repro.core import (agglomerate, distance_matrix, estimate_entropy,
                        expected_bias_update, label_entropy)
from repro.data import SyntheticSpec
from repro.fed import ExperimentSpec, LocalSpec, run_experiment


def _cohort_db(rng, n=40, c=10, frac_bal=0.25, scale=0.025):
    n_bal = int(n * frac_bal)
    dists = np.concatenate([
        np.stack([rng.dirichlet(np.full(c, 0.01))
                  for _ in range(n - n_bal)]),
        np.stack([rng.dirichlet(np.full(c, 10.0)) for _ in range(n_bal)]),
    ])
    e = jnp.full(c, 0.1)
    db = np.array(expected_bias_update(jnp.asarray(dists), e, scale, 2))
    db += rng.normal(0, 1e-4, db.shape)
    return dists, db, n - n_bal


def lam_ablation(rng) -> list:
    dists, db, n_imb = _cohort_db(rng)
    rows = []
    for lam in (0.0, 1.0, 10.0, 100.0):
        d = np.asarray(distance_matrix(jnp.asarray(db), 0.0025, lam))
        labels = agglomerate(d, 2, linkage="ward")
        # purity: balanced clients share one label not used by imbalanced
        bal = labels[n_imb:]
        imb = labels[:n_imb]
        pure = (len(set(bal)) == 1) and not (set(bal) & set(imb))
        # soft metric: majority-side fraction
        maj = max((bal == v).mean() for v in set(bal))
        rows.append((lam, bool(pure), float(maj)))
    return rows


def temp_ablation(rng) -> list:
    dists, db, _ = _cohort_db(rng)
    h_true = np.asarray(label_entropy(jnp.asarray(dists)))
    rows = []
    for t in (0.0005, 0.0025, 0.01, 0.05, 0.25):
        h = np.asarray(estimate_entropy(jnp.asarray(db), t))
        rows.append((t, float(np.corrcoef(h, h_true)[0, 1]),
                     float(np.ptp(h))))
    return rows


def gamma_ablation(rounds=30) -> list:
    rows = []
    for g0 in (0.0, 1.0, 4.0, 8.0):
        accs = []
        for seed in (0,):
            spec = ExperimentSpec(
                arch="paper-mlp", num_clients=30, num_select=3,
                rounds=rounds, alphas=(0.001, 0.01, 0.5),
                selector="hics",
                selector_kw={"temperature": 0.63, "gamma0": g0,
                             "normalize": True},
                data=SyntheticSpec(noise=0.5, proto_scale=1.2),
                local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.05,
                                epochs=2, batch_size=32),
                samples_train=4000, samples_test=1000, eval_every=5,
                seed=seed)
            hist = run_experiment(spec)
            accs.append(hist["test_acc"])
        m = np.mean(np.asarray(accs), axis=0)
        rows.append((g0, float(m[len(m) // 2]), float(m[-1])))
    return rows


def main(quick: bool = True):
    print("== bench_ablations (λ / T / γ⁰) ==", flush=True)
    rng = np.random.default_rng(0)
    lam = lam_ablation(rng)
    print(md_table(["λ", "pure split @M=2", "majority frac"],
                   [(l, p, f"{m:.2f}") for l, p, m in lam]))
    temp = temp_ablation(np.random.default_rng(0))
    print(md_table(["T", "corr(Ĥ, H)", "Ĥ range"],
                   [(t, f"{c:.3f}", f"{r:.2f}") for t, c, r in temp]))
    gam = gamma_ablation(rounds=20 if quick else 60)
    print(md_table(["γ⁰", "mid-run acc", "final acc"],
                   [(g, f"{a:.3f}", f"{b:.3f}") for g, a, b in gam]))
    save_result("ablations", {"lambda": lam, "temperature": temp,
                              "gamma0": gam})
    return {"lambda": lam, "temperature": temp, "gamma0": gam}


if __name__ == "__main__":
    main()
