"""Sync vs buffered-async training under system heterogeneity.

Drives the same (data, partition, model, selector) through the sync
scanned server and the buffered-async server (``repro.fed.
async_server``) across a ladder of latency models — identity (the
parity configuration), two straggler severities, heavy-tail and burst
arrivals — and records per-configuration throughput, the tick at which
train loss first reaches a shared target, and the buffer-fill /
aggregation-trigger counters ``bench_overhead._drive`` reports when
handed an async server.  Lands in ``BENCH_async.json`` at the repo
root so the sync-vs-async trajectory is tracked per PR (CI uploads it
as an artifact).

Throughput numbers include each driver's one-off scan compile — the
same "what one run actually pays" convention BENCH_round_loop.json
uses for the host loop.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.bench_overhead import _drive
from benchmarks.common import md_table, save_result
from repro.configs import get_config
from repro.fed import (AsyncConfig, AsyncFederatedServer, FedConfig,
                       FederatedServer, LatencySpec, LocalSpec,
                       ticks_to_loss)
from repro.models.classifier import make_classifier
from repro.scenarios import get_scenario, make_dataset, materialize

REPO_ROOT = Path(__file__).resolve().parent.parent
N, K, SEED = 20, 4, 0

#: increasing system heterogeneity, ≥ 3 non-identity traffic shapes
LADDER = (
    ("identity", LatencySpec()),
    ("stragglers_20pct", LatencySpec(kind="stragglers",
                                     straggler_frac=0.2,
                                     straggler_delay=4, seed=1)),
    ("stragglers_40pct", LatencySpec(kind="stragglers",
                                     straggler_frac=0.4,
                                     straggler_delay=8, seed=1)),
    ("heavy_tail", LatencySpec(kind="lognormal", mu=0.5, scale=0.9,
                               seed=1)),
    ("flash_crowd", LatencySpec(kind="flash_crowd", period=6)),
)


def _build(samples: int = 600):
    scn = get_scenario("dir_severe")
    cfg = get_config("paper-mlp")
    train, _, _ = make_dataset(scn, samples, 120, cfg.vocab_size, 0)
    cap = min(samples, max(1, 4 * samples // N))
    part = materialize(scn, SEED, train, cfg.vocab_size, N, cap)
    init_fn, apply_fn, _ = make_classifier(cfg, input_dim=scn.data.dim)
    idx = np.asarray(part.idx)
    return (init_fn, apply_fn, train, part,
            np.asarray(train["x"])[idx], np.asarray(train["y"])[idx],
            np.asarray(part.mask))


def main(quick: bool = True):
    print("== bench_async (sync vs buffered-async) ==", flush=True)
    ticks = 40 if quick else 200
    local = LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1, epochs=1,
                      batch_size=32)
    init_fn, apply_fn, train, part, cx, cy, cm = _build()

    fs = FederatedServer.from_partition(
        init_fn, apply_fn,
        FedConfig(num_clients=N, num_select=K, rounds=ticks,
                  selector="hics", local=local, eval_every=10 ** 6,
                  seed=SEED, jit_rounds=True),
        train["x"], train["y"], part)
    t0 = time.perf_counter()
    sh = fs.run()
    sync_s = time.perf_counter() - t0
    first, best = sh["train_loss"][0], min(sh["train_loss"])
    target = best + 0.25 * (first - best)
    sync_tt = next((t for t, l in enumerate(sh["train_loss"])
                    if l <= target), None)
    out = {
        "what": "sync scanned loop vs buffered-async server (hics, "
                "dir_severe partition) under increasing straggler "
                "severity; wall times include the one-off scan compile",
        "ticks": ticks, "num_clients": N, "num_select": K,
        "capacity": 2 * K, "threshold": K, "beta": 0.5,
        "target_loss": float(target),
        "sync": {"rounds_per_s": ticks / sync_s,
                 "rounds_to_target": sync_tt,
                 "final_loss": float(sh["train_loss"][-1])},
        "async": {},
    }
    print(f"  sync: {ticks / sync_s:6.2f} rounds/s  "
          f"to-target={sync_tt}", flush=True)
    rows = [["sync", f"{ticks / sync_s:.2f}", str(sync_tt),
             "-", "-", "-"]]
    for name, lat in LADDER:
        acfg = AsyncConfig(num_clients=N, num_select=K, ticks=ticks,
                           selector="hics", local=local, capacity=2 * K,
                           threshold=K, beta=0.5, latency=lat,
                           seed=SEED)
        srv = AsyncFederatedServer(init_fn, apply_fn, acfg, cx, cy, cm)
        stats = _drive(srv)
        h = stats.pop("history")
        tps = 1.0 / max(stats["s_per_tick"], 1e-12)
        cell = {"ticks_per_s": tps,
                "ticks_to_target": ticks_to_loss(h, target),
                "final_loss": float(h["train_loss"][-1]), **stats}
        out["async"][name] = cell
        rows.append([name, f"{tps:.2f}", str(cell["ticks_to_target"]),
                     str(cell["aggregations"]),
                     f"{cell['mean_fill']:.2f}",
                     str(cell["dropped_total"])])
        print(f"  async/{name:17s} {tps:6.2f} ticks/s  "
              f"to-target={cell['ticks_to_target']}  "
              f"aggs={cell['aggregations']}  "
              f"dropped={cell['dropped_total']}", flush=True)
    save_result("async_server", out)
    from benchmarks.common import stamp_env
    (REPO_ROOT / "BENCH_async.json").write_text(json.dumps(stamp_env(out),
                                                           indent=1))
    print(f"  wrote {REPO_ROOT / 'BENCH_async.json'}", flush=True)
    print(md_table(["config", "ticks/s", "to-target", "aggregations",
                    "mean fill", "dropped"], rows))
    return out


if __name__ == "__main__":
    main()
