"""§Roofline report: reads the dry-run artifacts
(benchmarks/artifacts/dryrun/*.json) and prints the three-term roofline
per (arch × shape × mesh), the dominant bottleneck, and the
MODEL_FLOPS/HLO ratio.  Run `python -m repro.launch.dryrun --all` first.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import md_table, save_result

DRY = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load_records(mesh: str | None = "pod1",
                 policy: str = "2d") -> list[dict]:
    recs = []
    for p in sorted(DRY.glob("*.json")):
        if p.stem.endswith("__fsdp") != (policy == "fsdp"):
            continue
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def main(quick: bool = True):
    print("== bench_roofline (from dry-run artifacts) ==", flush=True)
    if not DRY.exists():
        print("  NO ARTIFACTS — run: PYTHONPATH=src python -m "
              "repro.launch.dryrun --all")
        return None
    rows, payload = [], []
    for r in load_records("pod1"):
        if r.get("status") == "skipped":
            rows.append((r["arch"], r["shape"], "skipped", "", "", "", "",
                         ""))
            continue
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], "ERROR", "", "", "", "",
                         ""))
            continue
        t = r["roofline"]
        rows.append((
            r["arch"], r["shape"],
            f"{t['compute_s']:.3g}", f"{t['memory_s']:.3g}",
            f"{t['collective_s']:.3g}", t["bottleneck"],
            f"{t['useful_flops_ratio']:.2f}",
            f"{t['compute_fraction']:.2f}",
        ))
        payload.append({k: r[k] for k in
                        ("arch", "shape", "mesh", "roofline")})
    print(md_table(["arch", "shape", "compute_s", "memory_s",
                    "collective_s", "bottleneck", "useful_ratio",
                    "compute_frac"], rows))
    # multi-pod check: every pod2 record must be ok/skipped
    pod2 = load_records("pod2")
    bad = [r for r in pod2 if r.get("status") not in ("ok", "skipped")]
    print(f"\n  pod2 (2x16x16 = 512 chips): {len(pod2)} records, "
          f"{len(bad)} failures")
    # §Perf optimized-policy comparison (train shapes)
    opt = {(r["arch"], r["shape"]): r for r in load_records("pod1", "fsdp")
           if r.get("status") == "ok"}
    if opt:
        print("\n--- §Perf: collective term, baseline (2d) vs fsdp, "
              "train_4k ---")
        rows2 = []
        for r in load_records("pod1"):
            key = (r.get("arch"), r.get("shape"))
            if r.get("status") != "ok" or key not in opt \
                    or r["shape"] != "train_4k":
                continue
            b = r["roofline"]["collective_s"]
            f = opt[key]["roofline"]["collective_s"]
            rows2.append((r["arch"], f"{b:.3g}", f"{f:.3g}",
                          f"{b/f:.1f}x" if f else "inf",
                          opt[key]["roofline"]["bottleneck"]))
        print(md_table(["arch", "2d coll_s", "fsdp coll_s", "win",
                        "fsdp bottleneck"], rows2))
    save_result("roofline_summary", {"pod1": payload,
                                     "pod2_failures": len(bad),
                                     "pod2_records": len(pod2)})
    return payload


if __name__ == "__main__":
    main()
