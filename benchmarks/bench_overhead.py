"""Paper Table 3: per-round selection compute/communication overhead.

Measures the wall-time of select()+update() per selector while scaling
the model dimension |θ| (CS / DivFL / pow-d costs grow with |θ|) and the
class count C (HiCS-FL's only dimension), plus the fused-vs-unfused
selection-step comparison (one jitted sweep vs the stitched
entropy → norm → distance pipeline the selector used before).  The
fused numbers land in ``BENCH_selection.json`` at the repo root so the
perf trajectory is recorded per PR.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import md_table, save_result
from repro.core import make_selector

N, K, T = 50, 5, 100
REPO_ROOT = Path(__file__).resolve().parent.parent


def _drive(sel, db=None, full=None, losses=None, rounds=8,
           warmup: int = 2):
    """Steady-state s/round.  The shims jit their select/update
    transitions per instance, so the first rounds pay one-off compile
    time — warm them before starting the clock (Table 3 is about
    per-round overhead, not compilation).

    When handed an :class:`~repro.fed.async_server.
    AsyncFederatedServer` instead of a selector shim, drives the whole
    async tick loop and returns a dict: per-tick wall time (first
    ``warmup`` ticks excluded — they amortize the scan compile) plus
    the buffer-fill / aggregation-trigger counters the run accumulated
    (``bench_async``'s BENCH_async.json consumes these)."""
    from repro.fed.async_server import AsyncFederatedServer
    if isinstance(sel, AsyncFederatedServer):
        h = sel.run()
        # segment timings (ticks never surface to the host): drop the
        # first segment, which amortizes the scan compile, unless it is
        # the only one
        walls, counts = h["segment_wall_s"], h["segment_rounds"]
        if len(walls) > 1:
            walls, counts = walls[1:], counts[1:]
        return {"s_per_tick": float(sum(walls) / sum(counts)),
                "aggregations": int(h["aggregations"]),
                "fired_frac": float(np.mean(h["fired"])),
                "dropped_total": int(h["dropped_total"]),
                "mean_fill": float(h["mean_fill"]),
                "history": h}

    def one_round(t):
        ids = sel.select(t)
        sel.update(t, ids, bias_updates=db[ids],
                   full_updates=(full if "full_all" in sel.requires
                                 else full[ids]),
                   losses=losses)
    for t in range(warmup):
        one_round(t)
    t0 = time.perf_counter()
    for t in range(warmup, warmup + rounds):
        one_round(t)
    return (time.perf_counter() - t0) / rounds


def run() -> dict:
    rng = np.random.default_rng(0)
    out: dict = {}
    C = 10
    db = rng.normal(size=(N, C)) * 0.01
    losses = rng.random(N)
    for theta in (10_000, 100_000, 1_000_000):
        full = rng.normal(size=(N, theta)).astype(np.float32)
        for name in ("random", "pow-d", "cs", "divfl", "fedcor", "hics"):
            sel = make_selector(name, num_clients=N, num_select=K,
                                total_rounds=T)
            sec = _drive(sel, db, full, losses)
            out.setdefault(name, {})[theta] = sec
            print(f"  |θ|={theta:>9,d} {name:7s} {sec*1e3:8.2f} ms/round",
                  flush=True)
    # HiCS vs C (its only scaling dimension) using the Pallas path
    from repro.kernels import estimate_entropies, pairwise_distances
    import jax.numpy as jnp
    out["hics_vs_C"] = {}
    for C_big in (10, 1000, 32_768):
        db_big = jnp.asarray(rng.normal(size=(N, C_big)) * 0.01,
                             jnp.float32)
        t0 = time.perf_counter()
        h = estimate_entropies(db_big, 0.01, use_pallas=False)
        d = pairwise_distances(db_big, 0.01, use_pallas=False)
        d.block_until_ready()
        sec = time.perf_counter() - t0
        out["hics_vs_C"][C_big] = sec
        print(f"  C={C_big:>7,d} hics entropy+pairwise {sec*1e3:8.2f} ms",
              flush=True)
    return out


def selection_step_comparison() -> dict:
    """Fused (one jitted step) vs unfused (eager entropy → norm →
    distance, the seed selector path) on the CPU oracle backend."""
    import jax.numpy as jnp
    from repro.core.distance import distance_matrix
    from repro.core.hetero import estimate_entropy
    from repro.kernels import hics_selection_step

    rng = np.random.default_rng(0)
    out: dict = {}
    for (n, c) in ((64, 32_768), (256, 8192)):
        x = jnp.asarray(rng.normal(size=(n, c)) * 0.01, jnp.float32)

        def unfused():
            ent = estimate_entropy(x, 0.0025)
            return ent, distance_matrix(x, 0.0025, 10.0, entropies=ent)

        def fused():
            return hics_selection_step(x, 0.0025, lam=10.0,
                                       use_pallas=False)

        fused()[1].block_until_ready()          # jit warm-up
        t_u = t_f = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            unfused()[1].block_until_ready()
            t_u = min(t_u, time.perf_counter() - t0)
            t0 = time.perf_counter()
            fused()[1].block_until_ready()
            t_f = min(t_f, time.perf_counter() - t0)
        key = f"N{n}_C{c}"
        out[key] = {"n": n, "c": c, "unfused_seconds": t_u,
                    "fused_seconds": t_f, "speedup": t_u / t_f}
        print(f"  selection step N={n} C={c}: unfused {t_u*1e3:7.2f} ms"
              f"  fused {t_f*1e3:7.2f} ms  ({t_u/t_f:.2f}x)", flush=True)
    return out


def incremental_vs_full(ns=(64, 256, 512), k: int = 10, c: int = 1024,
                        repeats: int = 5) -> dict:
    """Incremental K-row refresh vs from-scratch selection step.

    Alg. 1 replaces K Δb rows per round; the cached path
    (``hics_selection_step_cached``) recomputes only the K×N strip and
    re-symmetrizes — O(K·N·C) per round against the full step's
    O(N²·C).  Timed per-round at steady state (compile excluded), both
    on the CPU oracle backend like the fused-vs-unfused entry; the TPU
    path swaps in the Pallas strip kernel.  Lands in
    ``BENCH_selection.json`` so the speedup trajectory is tracked per
    PR (acceptance floor: ≥2× at N=512, K=10)."""
    import jax.numpy as jnp
    from repro.kernels import (hics_selection_step,
                               hics_selection_step_cached)

    rng = np.random.default_rng(0)
    out: dict = {"k": k, "c": c}
    for n in ns:
        x = jnp.asarray(rng.normal(size=(n, c)) * 0.01, jnp.float32)
        # warm, fully-refreshed cache (what a steady-state round sees)
        _, dist, stats = hics_selection_step_cached(
            x, jnp.zeros((n, n)), jnp.zeros((n, 2)),
            jnp.arange(n, dtype=jnp.int32), 0.0025, lam=10.0,
            use_pallas=False)
        ids = jnp.asarray(rng.choice(n, size=k, replace=False),
                          jnp.int32)

        def full():
            return hics_selection_step(x, 0.0025, lam=10.0,
                                       use_pallas=False)

        def incremental():
            return hics_selection_step_cached(x, dist, stats, ids,
                                              0.0025, lam=10.0,
                                              use_pallas=False)

        full()[1].block_until_ready()           # compile both paths
        incremental()[1].block_until_ready()
        t_f = t_i = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            full()[1].block_until_ready()
            t_f = min(t_f, time.perf_counter() - t0)
            t0 = time.perf_counter()
            incremental()[1].block_until_ready()
            t_i = min(t_i, time.perf_counter() - t0)
        out[f"N={n}"] = {"full_seconds": t_f, "incremental_seconds": t_i,
                         "speedup": t_f / t_i}
        print(f"  selection N={n:4d} K={k} C={c}: full {t_f*1e3:8.2f} ms"
              f"  incremental {t_i*1e3:8.2f} ms  ({t_f/t_i:.2f}x)",
              flush=True)
    return out


def full_update_cached_vs_scratch(ns=(64, 256, 512), k: int = 10,
                                  f: int = 1024, repeats: int = 5
                                  ) -> dict:
    """Cached K-row refresh vs from-scratch (N, N) matrix build for the
    FULL-UPDATE selectors (CS's angular distance, DivFL's L2).

    PR 4 gave HiCS the O(K·N·C) incremental path; this is the same
    strip kernel with the Eq. 9 epilogue swapped for the selector's own
    metric (``repro.kernels.cached_feature_step``), so CS and DivFL's
    practical (participants-only) polling pay O(K·N·F) per round
    instead of the O(N²·F) Table 3 charges the from-scratch build.
    Timed per-round at steady state on the CPU oracle backend (compile
    excluded); the TPU path swaps in the Pallas strip kernel.  Lands in
    ``BENCH_selection.json`` (acceptance floor: cached beats scratch at
    N=512)."""
    import jax.numpy as jnp
    from repro.kernels import cached_feature_step

    rng = np.random.default_rng(0)
    out: dict = {"k": k, "f": f}
    for n in ns:
        x = jnp.asarray(rng.normal(size=(n, f)) * 0.01, jnp.float32)
        ids = jnp.asarray(rng.choice(n, size=k, replace=False),
                          jnp.int32)
        all_ids = jnp.arange(n, dtype=jnp.int32)
        for metric in ("cosine", "l2"):
            # warm, fully-refreshed cache (steady-state round input)
            dist, stats = cached_feature_step(
                x, jnp.zeros((n, n)), jnp.zeros((n, 2)), all_ids,
                metric=metric, use_pallas=False)

            def scratch():
                return cached_feature_step(
                    x, jnp.zeros((n, n)), jnp.zeros((n, 2)), all_ids,
                    metric=metric, use_pallas=False)

            def cached():
                return cached_feature_step(x, dist, stats, ids,
                                           metric=metric,
                                           use_pallas=False)

            scratch()[0].block_until_ready()    # compile both paths
            cached()[0].block_until_ready()
            t_s = t_c = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                scratch()[0].block_until_ready()
                t_s = min(t_s, time.perf_counter() - t0)
                t0 = time.perf_counter()
                cached()[0].block_until_ready()
                t_c = min(t_c, time.perf_counter() - t0)
            out[f"N={n}/{metric}"] = {
                "scratch_seconds": t_s, "cached_seconds": t_c,
                "speedup": t_s / t_c}
            print(f"  full-update N={n:4d} K={k} F={f} {metric:6s}: "
                  f"scratch {t_s*1e3:8.2f} ms  cached {t_c*1e3:8.2f} ms"
                  f"  ({t_s/t_c:.2f}x)", flush=True)
    return out


def clustering_scaling(ns=(64, 256, 512), repeats: int = 3) -> dict:
    """``agglomerate_device`` (naive O(N³), on-device) vs the numpy
    lazy-min-cache ``agglomerate`` (amortized O(N²)) — the clustering
    cost the sweep engine pays inside every vmapped selection step, so
    its scaling must stay visible in the per-PR trajectory."""
    import jax
    import jax.numpy as jnp
    from repro.core import agglomerate, agglomerate_device

    rng = np.random.default_rng(0)
    out: dict = {}
    for n in ns:
        x = rng.normal(size=(n, 8))
        dist = np.sqrt(((x[:, None] - x[None, :]) ** 2).sum(-1))
        # the selection path hands over an exactly-symmetric matrix, so
        # the bench exercises the same precomputed fast path it uses
        dev = jax.jit(lambda d: agglomerate_device(d, 8,
                                                   precomputed=True))
        dev(jnp.asarray(dist)).block_until_ready()      # compile
        t_dev = t_np = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            dev(jnp.asarray(dist)).block_until_ready()
            t_dev = min(t_dev, time.perf_counter() - t0)
            t0 = time.perf_counter()
            agglomerate(dist, 8, precomputed=True)
            t_np = min(t_np, time.perf_counter() - t0)
        out[f"N={n}"] = {"device_seconds": t_dev, "numpy_seconds": t_np,
                         "device_over_numpy": t_dev / t_np}
        print(f"  agglomerate N={n:4d}: device {t_dev*1e3:8.2f} ms  "
              f"numpy(lazy-min) {t_np*1e3:8.2f} ms", flush=True)
    return out


def main(quick: bool = True):
    print("== bench_overhead (Table 3 analogue) ==", flush=True)
    res = run()
    sel = selection_step_comparison()
    res["selection_step"] = sel
    ivf = incremental_vs_full()
    res["incremental_vs_full"] = ivf
    fucs = full_update_cached_vs_scratch()
    res["full_update_cached_vs_scratch"] = fucs
    clus = clustering_scaling()
    res["clustering_scaling"] = clus
    save_result("table3_overhead", res)
    # repo-root perf trajectory artifact (one file per concern)
    from benchmarks.common import stamp_env
    (REPO_ROOT / "BENCH_selection.json").write_text(json.dumps(stamp_env({
        "what": "fused vs unfused HiCS selection step (CPU oracle "
                "backend; TPU path is the Pallas kernel pipeline)",
        "pre_gram_hbm_sweeps": {"fused": 1, "unfused": 3},
        "results": sel,
        "incremental_vs_full": ivf,
        "full_update_cached_vs_scratch": fucs,
        "clustering_scaling": clus,
    }), indent=1))
    print(f"  wrote {REPO_ROOT / 'BENCH_selection.json'}", flush=True)
    thetas = sorted(next(iter(res.values())).keys()) \
        if "random" in res else []
    rows = []
    for name in ("random", "pow-d", "cs", "divfl", "fedcor", "hics"):
        rows.append([name] + [f"{res[name][t]*1e3:.2f}"
                              for t in (10_000, 100_000, 1_000_000)])
    print(md_table(["selector", "ms/round |θ|=10k", "|θ|=100k",
                    "|θ|=1M"], rows))
    print("\nHiCS-FL scales only with C:",
          {k: f"{v*1e3:.1f}ms" for k, v in res["hics_vs_C"].items()})
    return res


if __name__ == "__main__":
    main()
