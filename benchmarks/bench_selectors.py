"""Paper Tables 1 + 2 analogue: final test accuracy and
rounds-to-target-accuracy for all six selectors across the three
multi-α heterogeneity settings, on the synthetic classification
substitute (DESIGN.md §7) — plus the round-loop redesign benchmark
(scanned ``jit_rounds=True`` vs the host loop), written to
``BENCH_round_loop.json`` at the repo root.

Settings mirror §4.1 (FMNIST block):
  (1) 80% severely imbalanced + 20% balanced        α={1e-3..1e-2, 0.5}
  (2) 80% severely imbalanced + 20% mildly imbal.   α={1e-3..1e-2, 0.2}
  (3) all severely imbalanced                       α={1e-3}
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import md_table, save_result, savitzky_golay
from repro.data import SyntheticSpec
from repro.fed import (ExperimentSpec, LocalSpec, build,
                       rounds_to_accuracy, run_experiment)

REPO_ROOT = Path(__file__).resolve().parent.parent

SETTINGS = {
    "setting1": (0.001, 0.002, 0.005, 0.01, 0.5),
    "setting2": (0.001, 0.002, 0.005, 0.01, 0.2),
    "setting3": (0.001,),
}

SELECTORS = {
    "random": ("random", None),
    "pow-d": ("pow-d", None),
    "cs": ("cs", None),
    "divfl": ("divfl", None),
    "fedcor": ("fedcor", None),
    "hics (paper)": ("hics", {"temperature": 0.05, "gamma0": 4.0}),
    "hics (norm)": ("hics", {"temperature": 0.63, "gamma0": 4.0,
                             "normalize": True}),
}


def run(rounds: int = 100, seeds=(0,), num_clients: int = 50,
        num_select: int = 5, target: float = 0.6) -> dict:
    results: dict = {}
    for sname, alphas in SETTINGS.items():
        results[sname] = {}
        for label, (sel, kw) in SELECTORS.items():
            accs, rts, var = [], [], []
            for seed in seeds:
                spec = ExperimentSpec(
                    arch="paper-mlp", num_clients=num_clients,
                    num_select=num_select, rounds=rounds, alphas=alphas,
                    selector=sel, selector_kw=kw,
                    data=SyntheticSpec(noise=0.5, proto_scale=1.2),
                    local=LocalSpec(algo="fedavg", optimizer="sgd",
                                    lr=0.05, epochs=2, batch_size=32),
                    samples_train=10_000, samples_test=2_000,
                    eval_every=5, seed=seed)
                hist = run_experiment(spec)
                accs.append(hist["test_acc"][-1])
                rt = rounds_to_accuracy(hist, target)
                rts.append(rounds if rt is None else rt)
                # training-loss variance after smoothing (Fig. 3 analogue)
                tl = np.asarray(hist["train_loss"])
                var.append(float(np.var(tl - savitzky_golay(tl))))
            results[sname][label] = {
                "final_acc": float(np.mean(accs)),
                "final_acc_std": float(np.std(accs)),
                f"rounds_to_{target}": float(np.mean(rts)),
                "loss_var": float(np.mean(var)),
            }
            print(f"  {sname} {label:14s} acc={np.mean(accs):.3f} "
                  f"r@{target}={np.mean(rts):.0f} "
                  f"lossvar={np.mean(var):.4f}", flush=True)
    return results


def bench_round_loop(ns=(64, 256, 512), rounds: int = 10,
                     num_select: int = 8) -> dict:
    """Rounds/sec of the scanned round loop vs the host loop (HiCS).

    Each N gets a tiny per-client dataset so the comparison isolates
    the round-loop machinery (selection, dispatch, host transfers)
    rather than local-update FLOPs.  Compile time is excluded by
    warming both paths with one full run before timing."""
    out: dict = {}
    for n in ns:
        spec = ExperimentSpec(
            arch="paper-mlp", num_clients=n, num_select=num_select,
            rounds=rounds, alphas=(0.01, 0.5), selector="hics",
            local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.05,
                            epochs=1, batch_size=16),
            samples_train=4 * n, samples_test=64, eval_every=10 ** 6,
            seed=0)
        res = {}
        for label, jit_rounds in (("host", False), ("scan", True)):
            server, _ = build(spec)
            server.run(jit_rounds=jit_rounds)       # warm-up + compile
            t0 = time.perf_counter()
            server.run(jit_rounds=jit_rounds)
            dt = time.perf_counter() - t0
            res[f"{label}_rounds_per_s"] = rounds / dt
        res["speedup"] = (res["scan_rounds_per_s"]
                          / res["host_rounds_per_s"])
        out[f"N={n}"] = res
        print(f"  N={n:4d}  host={res['host_rounds_per_s']:7.1f} r/s  "
              f"scan={res['scan_rounds_per_s']:7.1f} r/s  "
              f"({res['speedup']:.2f}x)", flush=True)
    return out


def main(quick: bool = True):
    print("== bench_round_loop (jitted scan vs host loop) ==", flush=True)
    rl = bench_round_loop(ns=(64, 256, 512), rounds=10 if quick else 30)
    save_result("round_loop", rl)
    from benchmarks.common import stamp_env
    (REPO_ROOT / "BENCH_round_loop.json").write_text(
        json.dumps(stamp_env(rl), indent=1))
    print(f"  wrote {REPO_ROOT / 'BENCH_round_loop.json'}", flush=True)

    print("== bench_selectors (Tables 1+2 analogue) ==", flush=True)
    rounds = 60 if quick else 150
    seeds = (0,) if quick else (0, 1, 2)
    res = run(rounds=rounds, seeds=seeds, target=0.5 if quick else 0.6)
    save_result("table1_table2_selectors", res)
    key_rt = [k for k in next(iter(
        next(iter(res.values())).values())) if k.startswith("rounds")][0]
    for sname in res:
        rows = [(lbl, f"{d['final_acc']:.3f}", f"{d[key_rt]:.0f}",
                 f"{d['loss_var']:.4f}")
                for lbl, d in res[sname].items()]
        base = res[sname]["random"][key_rt]
        rows = [(r[0], r[1], r[2],
                 f"{base / max(float(r[2]), 1):.1f}x", r[3])
                for r in rows]
        print(f"\n--- {sname} ---")
        print(md_table(["selector", "final acc", key_rt, "speedup",
                        "loss var"], rows))
    return res


if __name__ == "__main__":
    main()
