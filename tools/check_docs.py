#!/usr/bin/env python
"""Docs checker: executable snippets + intra-repo links.

Scans README.md and docs/*.md and fails if

  1. any fenced ``python`` code block fails to execute (each block runs
     in its own namespace, with ``src/`` on sys.path — so every snippet
     in the docs is a live, tested example.  Tag a fence
     ``python no-run`` to exempt pseudo-code), or
  2. any relative markdown link ``[text](path)`` points at a file that
     does not exist in the repo.

Run from anywhere:  python tools/check_docs.py
CI runs this as the ``docs`` job (.github/workflows/ci.yml).
"""
from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

FENCE = re.compile(r"^```(\S*)[ \t]*(.*)$")
# [text](target) — excluding images' inner brackets is unnecessary:
# ![alt](img) matches too, and image targets must exist just the same
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def iter_code_blocks(text: str):
    """Yield (info_string, extra, code) for each fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and lines[i].startswith("```") and m.group(1):
            lang, extra = m.group(1), m.group(2).strip()
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            yield lang, extra, "\n".join(body)
        i += 1


def check_snippets(path: Path) -> list[str]:
    errors = []
    for n, (lang, extra, code) in enumerate(
            iter_code_blocks(path.read_text()), 1):
        if lang != "python" or "no-run" in extra:
            continue
        try:
            exec(compile(code, f"{path.name}#block{n}", "exec"), {})
        except Exception:
            errors.append(f"{path}: python block {n} failed:\n"
                          f"{traceback.format_exc(limit=3)}")
    return errors


def check_links(path: Path) -> list[str]:
    errors = []
    for target in LINK.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            errors.append(f"{path}: broken link -> {target}")
    return errors


def main() -> int:
    docs = [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))
    errors = []
    for doc in docs:
        if not doc.exists():
            errors.append(f"missing doc: {doc}")
            continue
        errors += check_links(doc)
        errors += check_snippets(doc)
        print(f"checked {doc.relative_to(REPO)}")
    if errors:
        print("\n".join(["", "DOCS CHECK FAILED:"] + errors))
        return 1
    print("docs OK: all snippets executed, all intra-repo links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
