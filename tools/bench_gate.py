#!/usr/bin/env python
"""Bench regression gate: fresh BENCH_*.json vs committed baselines.

Compares the ``BENCH_*.json`` trajectory artifacts at the repo root
(the "fresh" run, produced by ``python -m benchmarks.run`` or the
individual ``benchmarks/bench_*.py`` scripts) against committed
baselines and fails when a tracked metric regresses beyond its
relative tolerance.

Baselines come from ``git show HEAD:BENCH_<name>.json`` by default,
so the gate answers "did *this* change slow anything down?".
Pass ``--baseline-dir DIR`` to compare against a directory of saved
artifacts instead.

Rules are glob-style dotted paths into the JSON (``results.*.speedup``)
with a direction (higher- or lower-is-better) and a relative tolerance.
Wall-clock numbers on shared runners are noisy, so tolerances are
deliberately generous — the gate exists to catch real regressions
(2x slowdowns from an accidental re-jit), not 5% jitter.

Every artifact carries an ``env`` stamp (see
``repro.telemetry.export.env_stamp``).  When fresh and baseline stamps
disagree on backend / device kind / CPU count the numbers are not
comparable; the gate *skips* that file with a notice instead of
reporting phantom regressions (exit 0).

Usage:
    python tools/bench_gate.py                  # gate vs HEAD
    python tools/bench_gate.py --baseline-dir saved/
    python tools/bench_gate.py --selftest       # verify the gate works

CI runs ``--selftest`` (the gate must catch an injected 25% regression
and pass the untouched artifacts) and then the real gate.
"""
from __future__ import annotations

import argparse
import copy
import fnmatch
import json
import subprocess
import sys
from dataclasses import dataclass
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

# BENCH_*.json trajectory artifacts live at the repo root
ART = REPO

HIGHER, LOWER = "higher", "lower"


@dataclass(frozen=True)
class Rule:
    pattern: str        # glob-style dotted path, e.g. "results.*.speedup"
    direction: str      # HIGHER or LOWER is better
    rtol: float         # relative tolerance before flagging


# Tracked metrics per artifact.  Ratios (speedups) are steadier than raw
# wall-clock, so they get tighter tolerances; absolute throughput gets
# looser ones.  Paths are matched segment-wise with fnmatch.
RULES: dict[str, list[Rule]] = {
    "BENCH_selection.json": [
        # same-machine timing ratio — stable, and the CI acceptance bar
        # is "a >=20% drop here must fail", so the tolerance sits below
        Rule("results.*.speedup", HIGHER, 0.15),
        Rule("incremental_vs_full.*.speedup", HIGHER, 0.30),
        Rule("full_update_cached_vs_scratch.*.speedup", HIGHER, 0.30),
        # single-shot ms-scale timings in both numerator and
        # denominator — flaps ~1.8x run to run on CPU, so only a >2x
        # drift (an algorithmic regression) is signal
        Rule("clustering_scaling.*.device_over_numpy", LOWER, 1.00),
    ],
    "BENCH_round_loop.json": [
        Rule("*.host_rounds_per_s", HIGHER, 0.40),
        Rule("*.scan_rounds_per_s", HIGHER, 0.40),
        Rule("*.speedup", HIGHER, 0.35),
    ],
    # speedup_vs_serial is deliberately NOT gated: at the quick tier it
    # is a ratio of two ~50ms wall times and flaps ±2x run to run.
    # speedup_vs_host divides a multi-second host loop by vmapped_s, so
    # the ratio is large and far steadier.
    "BENCH_sweep.json": [
        Rule("grid.*.speedup_vs_host", HIGHER, 0.60),
        Rule("grid.*.vmapped_s", LOWER, 0.60),
    ],
    "BENCH_async.json": [
        Rule("sync.rounds_per_s", HIGHER, 0.40),
        Rule("async.*.ticks_per_s", HIGHER, 0.40),
        Rule("async.*.s_per_tick", LOWER, 0.60),
    ],
}


def flatten(obj, prefix: str = "") -> dict[str, float]:
    """Numeric leaves of a nested dict as {dotted.path: value}."""
    out: dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            p = f"{prefix}.{k}" if prefix else str(k)
            out.update(flatten(v, p))
    elif isinstance(obj, bool):
        pass                       # bool is an int subclass — exclude
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def match(pattern: str, path: str) -> bool:
    """Segment-wise glob match so ``*`` never crosses a dot."""
    pp, sp = pattern.split("."), path.split(".")
    return len(pp) == len(sp) and all(
        fnmatch.fnmatch(s, p) for p, s in zip(pp, sp))


def git_baseline(name: str) -> dict | None:
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{name}"],
            cwd=REPO, capture_output=True, text=True, check=True).stdout
        return json.loads(blob)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


@dataclass
class Row:
    file: str
    path: str
    base: float
    fresh: float
    rtol: float
    direction: str

    @property
    def change(self) -> float:
        """Relative change, signed so positive is always 'better'."""
        if self.base == 0:
            return 0.0
        raw = (self.fresh - self.base) / abs(self.base)
        return raw if self.direction == HIGHER else -raw

    @property
    def regressed(self) -> bool:
        return self.change < -self.rtol


def gate_file(name: str, fresh: dict, base: dict) -> tuple[list[Row], str]:
    """Returns (rows, skip_reason). Empty skip_reason == comparable."""
    from repro.telemetry.export import COMPARE_KEYS, env_comparable

    fe, be = fresh.get("env"), base.get("env")
    if fe and be and not env_comparable(fe, be):
        diff = {k: (be.get(k), fe.get(k)) for k in COMPARE_KEYS
                if be.get(k) != fe.get(k)}
        return [], f"env mismatch {diff} — numbers not comparable"

    f_flat, b_flat = flatten(fresh), flatten(base)
    rows = []
    for rule in RULES.get(name, []):
        for path, fval in sorted(f_flat.items()):
            if match(rule.pattern, path) and path in b_flat:
                rows.append(Row(name, path, b_flat[path], fval,
                                rule.rtol, rule.direction))
    return rows, ""


def print_table(rows: list[Row]) -> None:
    headers = ["metric", "baseline", "fresh", "change", "tol", "status"]
    table = []
    for r in rows:
        table.append([
            f"{r.file}:{r.path}", f"{r.base:.4g}", f"{r.fresh:.4g}",
            f"{r.change:+.1%}", f"±{r.rtol:.0%}",
            "REGRESSED" if r.regressed else "ok"])
    widths = [max(len(h), *(len(t[i]) for t in table)) if table else len(h)
              for i, h in enumerate(headers)]
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for t in table:
        print("  ".join(c.ljust(w) for c, w in zip(t, widths)))


def run_gate(baseline_dir: Path | None = None,
             fresh_dir: Path | None = None) -> int:
    fresh_dir = fresh_dir or ART
    any_rows, regressions, checked = [], [], 0
    for name in sorted(RULES):
        fp = fresh_dir / name
        if not fp.exists():
            print(f"[bench-gate] {name}: no fresh artifact — skipped")
            continue
        fresh = json.loads(fp.read_text())
        if baseline_dir is not None:
            bp = baseline_dir / name
            base = json.loads(bp.read_text()) if bp.exists() else None
        else:
            base = git_baseline(name)
        if base is None:
            print(f"[bench-gate] {name}: no baseline — skipped")
            continue
        rows, skip = gate_file(name, fresh, base)
        if skip:
            print(f"[bench-gate] {name}: SKIP ({skip})")
            continue
        checked += 1
        any_rows.extend(rows)
        regressions.extend(r for r in rows if r.regressed)

    if any_rows:
        print()
        print_table(any_rows)
        print()
    if regressions:
        print(f"[bench-gate] FAIL: {len(regressions)} metric(s) regressed "
              f"beyond tolerance across {checked} artifact(s).")
        return 1
    print(f"[bench-gate] OK: {len(any_rows)} metric(s) across "
          f"{checked} artifact(s) within tolerance.")
    return 0


def selftest(baseline_dir: Path | None) -> int:
    """The gate must (a) pass the real artifacts and (b) catch an
    injected 25% drop in a BENCH_selection.json speedup."""
    import tempfile

    print("[bench-gate] selftest: real artifacts should pass")
    if run_gate(baseline_dir) != 0:
        print("[bench-gate] selftest FAIL: real artifacts were flagged")
        return 1

    src = ART / "BENCH_selection.json"
    if not src.exists():
        print("[bench-gate] selftest FAIL: BENCH_selection.json missing")
        return 1
    doc = json.loads(src.read_text())
    injected = copy.deepcopy(doc)
    paths = [p for p in flatten(injected)
             if match("results.*.speedup", p)]
    if not paths:
        print("[bench-gate] selftest FAIL: no results.*.speedup metric")
        return 1
    _, group, leaf = paths[0].split(".")
    injected["results"][group][leaf] *= 0.75        # 25% regression

    with tempfile.TemporaryDirectory() as td:
        tmp = Path(td)
        (tmp / "BENCH_selection.json").write_text(json.dumps(injected))
        print(f"\n[bench-gate] selftest: injected -25% into "
              f"{paths[0]}; gate should fail")
        rc = run_gate(baseline_dir, fresh_dir=tmp)
    if rc == 0:
        print("[bench-gate] selftest FAIL: injected regression not caught")
        return 1
    print("\n[bench-gate] selftest OK: clean pass + injected fail caught")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", type=Path, default=None,
                    help="compare against this directory instead of "
                         "git HEAD's committed artifacts")
    ap.add_argument("--selftest", action="store_true",
                    help="verify the gate catches an injected regression")
    args = ap.parse_args(argv)
    if args.selftest:
        return selftest(args.baseline_dir)
    return run_gate(args.baseline_dir)


if __name__ == "__main__":
    sys.exit(main())
