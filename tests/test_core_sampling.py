"""Two-stage annealed sampling (paper §3.4, Eq. 10)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (anneal, cluster_probs, hierarchical_sample,
                        sampling_probabilities)


def test_anneal_schedule():
    assert anneal(4.0, 0, 100) == pytest.approx(4.0)
    assert anneal(4.0, 50, 100) == pytest.approx(2.0)
    assert anneal(4.0, 100, 100) == pytest.approx(0.0)
    assert anneal(4.0, 150, 100) == 0.0          # clipped


@settings(deadline=None, max_examples=40)
@given(st.integers(1, 12), st.floats(0.0, 8.0), st.integers(0, 2**31 - 1))
def test_cluster_probs_simplex(m, gamma, seed):
    r = np.random.default_rng(seed)
    h = r.uniform(0, np.log(10), m)
    p = cluster_probs(h, gamma)
    assert p.shape == (m,)
    assert np.all(p >= 0)
    assert np.sum(p) == pytest.approx(1.0, abs=1e-9)


def test_cluster_probs_monotone_in_entropy():
    p = cluster_probs(np.array([0.5, 1.0, 2.0]), gamma_t=3.0)
    assert p[0] < p[1] < p[2]
    # gamma 0 -> uniform over clusters
    p0 = cluster_probs(np.array([0.5, 1.0, 2.0]), gamma_t=0.0)
    np.testing.assert_allclose(p0, 1 / 3, atol=1e-9)


def test_hierarchical_sample_distinct(rng):
    labels = np.array([0] * 10 + [1] * 10 + [2] * 10)
    means = np.array([0.1, 1.0, 2.2])
    w = np.ones(30)
    for k in (1, 5, 15, 30):
        ids = hierarchical_sample(rng, labels, means, w, k, gamma_t=2.0)
        assert len(ids) == k
        assert len(set(ids)) == k
        assert all(0 <= i < 30 for i in ids)


def test_hierarchical_sample_prefers_high_entropy_cluster(rng):
    labels = np.array([0] * 20 + [1] * 5)
    means = np.array([0.1, 2.2])       # cluster 1 = balanced clients
    w = np.ones(25)
    hits = 0
    for _ in range(300):
        ids = hierarchical_sample(rng, labels, means, w, 1, gamma_t=4.0)
        hits += ids[0] >= 20
    assert hits > 270      # π_1 ≈ e^{4·2.2}/(e^{4·0.1}+e^{4·2.2}) ≈ 1


def test_within_cluster_weighting(rng):
    """Stage 2: p̃_k ∝ p_k inside the chosen cluster."""
    labels = np.zeros(3, dtype=int)
    means = np.array([1.0])
    w = np.array([1.0, 2.0, 7.0])
    counts = np.zeros(3)
    for _ in range(4000):
        ids = hierarchical_sample(rng, labels, means, w, 1, gamma_t=1.0)
        counts[ids[0]] += 1
    freq = counts / counts.sum()
    np.testing.assert_allclose(freq, w / w.sum(), atol=0.03)


def test_sampling_probabilities_marginal(rng):
    labels = np.array([0, 0, 1, 1, 1])
    means = np.array([0.5, 2.0])
    w = np.array([1.0, 3.0, 1.0, 1.0, 2.0])
    p = sampling_probabilities(labels, means, w, gamma_t=2.0)
    assert p.sum() == pytest.approx(1.0)
    pi = cluster_probs(means, 2.0)
    np.testing.assert_allclose(p[:2].sum(), pi[0], atol=1e-9)
    # within cluster 0: 1:3 ratio
    assert p[1] / p[0] == pytest.approx(3.0)
    # empirical single-draw frequencies match the marginal
    counts = np.zeros(5)
    for _ in range(6000):
        ids = hierarchical_sample(rng, labels, means, w, 1, gamma_t=2.0)
        counts[ids[0]] += 1
    np.testing.assert_allclose(counts / counts.sum(), p, atol=0.03)
