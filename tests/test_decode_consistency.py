"""Cache correctness: for every architecture, decoding tokens one at a
time against the cache must produce the same logits as a fresh prefill
of the extended sequence (teacher forcing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import get_model

ASSIGNED = [a for a in list_archs() if not a.startswith("paper-")]
EXTRA = 3


def _prefill_batch(cfg, rng, B, S):
    if cfg.kind == "vlm":
        P = cfg.vlm.num_patches
        return {"patches": jnp.asarray(
                    rng.normal(size=(B, P, cfg.vlm.patch_embed_dim)),
                    jnp.float32),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S - P)),
                    jnp.int32)}
    if cfg.kind == "audio":
        F = min(cfg.encdec.max_source_frames, S)
        return {"frames": jnp.asarray(
                    rng.normal(size=(B, F, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}


def _extend(batch, cfg, new_tokens):
    out = dict(batch)
    out["tokens"] = jnp.concatenate([batch["tokens"], new_tokens], axis=1)
    return out


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_prefill(arch, rng):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        pytest.skip("MoE capacity dropping differs between a full-span "
                    "prefill (C slots per S tokens) and token-by-token "
                    "decode (C per token) by design — train/serve routing "
                    "is not bit-identical in capacity-based MoE")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _prefill_batch(cfg, rng, B, S)
    new_toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, EXTRA)),
                           jnp.int32)

    # path A: prefill S, then decode EXTRA tokens through the cache.
    # prefill caches are sized to the prefill span; decode needs room
    # for EXTRA more -> build a fresh cache of the right length and
    # replay the whole prefix through decode_step (also exercises the
    # cache-update path position by position).
    total = S + EXTRA
    cache = api.init_cache(B, total, dtype=jnp.float32)
    if cfg.kind == "vlm":
        prefix = batch["tokens"]
        offset = cfg.vlm.num_patches
        pytest.skip("vlm decode replays only the text suffix; covered by "
                    "the transformer archs below")
    elif cfg.kind == "audio":
        # enc-dec: cache carries cross-attn K/V from the encoder; use
        # the api's prefill cache then decode (cache has headroom of
        # seq_len = total)
        cache = None
        prefix = batch["tokens"]
    else:
        prefix = batch["tokens"]

    if cfg.kind == "audio":
        logits_a, cache = api.prefill(params, batch, dtype=jnp.float32,
                                      cache_extra=EXTRA)
        pos = prefix.shape[1]
        last = None
        for i in range(EXTRA):
            last, cache = api.decode_step(
                params, cache, {"token": new_toks[:, i:i + 1],
                                "pos": jnp.asarray(pos + i, jnp.int32)},
                dtype=jnp.float32)
    else:
        last = None
        for i in range(prefix.shape[1] + EXTRA):
            tok = (prefix[:, i:i + 1] if i < prefix.shape[1]
                   else new_toks[:, i - prefix.shape[1]:
                                 i - prefix.shape[1] + 1])
            last, cache = api.decode_step(
                params, cache, {"token": tok,
                                "pos": jnp.asarray(i, jnp.int32)},
                dtype=jnp.float32)

    # path B: one prefill over the full extended sequence
    full = _extend(batch, cfg, new_toks)
    logits_b, _ = api.prefill(params, full, dtype=jnp.float32)

    a = np.asarray(last[:, -1, :], np.float32)
    b = np.asarray(logits_b[:, -1, :], np.float32)
    # compare top-1 and logit values (loose: different compute orders)
    np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)
    assert (a.argmax(-1) == b.argmax(-1)).all()
