"""Agglomerative clustering + Eq. 9 distance (paper §3.3-3.4)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (agglomerate, cluster_means, distance_matrix,
                        pairwise_arccos)


def _blob_dist(rng, sizes, sep=10.0):
    """Distance matrix of 1-D blobs with separation `sep`."""
    pts = np.concatenate([rng.normal(i * sep, 0.1, s)
                          for i, s in enumerate(sizes)])
    return np.abs(pts[:, None] - pts[None, :]), pts


@pytest.mark.parametrize("linkage", ["ward", "average", "complete",
                                     "single"])
def test_recovers_separated_blobs(rng, linkage):
    d, pts = _blob_dist(rng, (5, 7, 4))
    labels = agglomerate(d, 3, linkage=linkage)
    assert len(np.unique(labels)) == 3
    # items of one blob share one label
    assert len(set(labels[:5])) == 1
    assert len(set(labels[5:12])) == 1
    assert len(set(labels[12:])) == 1


def test_num_clusters_edges(rng):
    d, _ = _blob_dist(rng, (3, 3))
    assert len(np.unique(agglomerate(d, 1))) == 1
    assert len(np.unique(agglomerate(d, 6))) == 6      # no merges
    assert len(np.unique(agglomerate(d, 99))) == 6     # clipped at N


@settings(deadline=None, max_examples=30)
@given(st.integers(2, 25), st.integers(1, 8), st.integers(0, 2**31 - 1),
       st.sampled_from(["ward", "average", "complete", "single"]))
def test_label_invariants(n, m, seed, linkage):
    """Any symmetric matrix: labels in [0, M'), M' = min(m, n), and the
    relabelling is by first appearance (label 0 appears at index 0)."""
    r = np.random.default_rng(seed)
    a = r.uniform(0.1, 5.0, (n, n))
    d = 0.5 * (a + a.T)
    np.fill_diagonal(d, 0.0)
    labels = agglomerate(d, m, linkage=linkage)
    k = min(m, n)
    assert labels.shape == (n,)
    assert set(labels) == set(range(k))
    assert labels[0] == 0


def test_deterministic(rng):
    a = rng.uniform(size=(12, 12))
    d = 0.5 * (a + a.T)
    l1 = agglomerate(d, 4)
    l2 = agglomerate(d, 4)
    np.testing.assert_array_equal(l1, l2)


def test_cluster_means():
    vals = np.array([1.0, 2.0, 3.0, 10.0])
    labels = np.array([0, 0, 1, 1])
    np.testing.assert_allclose(cluster_means(vals, labels, 2), [1.5, 6.5])


def _agglomerate_naive(dist, num_clusters, linkage="ward"):
    """The original O(N³) flat-argmin implementation, kept verbatim as
    the semantics reference for the lazy-cache fast path."""
    n = dist.shape[0]
    num_clusters = max(1, min(num_clusters, n))
    d = np.array(dist, dtype=np.float64)
    d = 0.5 * (d + d.T)
    if linkage == "ward":
        d = d ** 2
    np.fill_diagonal(d, np.inf)
    active = np.ones(n, dtype=bool)
    sizes = np.ones(n, dtype=np.int64)
    labels = np.arange(n)
    for _ in range(n - num_clusters):
        i, j = np.unravel_index(np.argmin(d), d.shape)
        if i > j:
            i, j = j, i
        ni, nj = sizes[i], sizes[j]
        k_mask = active.copy()
        k_mask[i] = k_mask[j] = False
        dik, djk = d[i, k_mask], d[j, k_mask]
        if linkage == "ward":
            nk = sizes[k_mask].astype(np.float64)
            new = ((ni + nk) * dik + (nj + nk) * djk
                   - nk * d[i, j]) / (ni + nj + nk)
        elif linkage == "average":
            new = (ni * dik + nj * djk) / (ni + nj)
        elif linkage == "complete":
            new = np.maximum(dik, djk)
        else:
            new = np.minimum(dik, djk)
        d[i, k_mask] = new
        d[k_mask, i] = new
        d[j, :] = np.inf
        d[:, j] = np.inf
        active[j] = False
        sizes[i] = ni + nj
        labels[labels == labels[j]] = labels[i]
    uniq: dict = {}
    out = np.empty(n, dtype=np.int64)
    for idx, lab in enumerate(labels):
        if lab not in uniq:
            uniq[lab] = len(uniq)
        out[idx] = uniq[lab]
    return out


@pytest.mark.parametrize("linkage", ["ward", "average", "complete",
                                     "single"])
def test_labels_identical_to_naive_reference(rng, linkage):
    """The vectorized merge loop must be label-for-label identical to
    the naive flat-argmin implementation, including exact-tie order."""
    for trial in range(40):
        n = int(rng.integers(2, 50))
        m = int(rng.integers(1, 9))
        a = rng.uniform(0.1, 5.0, (n, n))
        d = 0.5 * (a + a.T)
        np.fill_diagonal(d, 0.0)
        np.testing.assert_array_equal(
            agglomerate(d, m, linkage=linkage),
            _agglomerate_naive(d, m, linkage=linkage))
    # heavy exact ties (integer-valued distances)
    for trial in range(20):
        n = int(rng.integers(3, 30))
        a = rng.integers(1, 5, (n, n)).astype(float)
        d = 0.5 * (a + a.T)
        np.fill_diagonal(d, 0.0)
        np.testing.assert_array_equal(
            agglomerate(d, 3, linkage=linkage),
            _agglomerate_naive(d, 3, linkage=linkage))


def test_agglomerate_faster_than_naive_at_512(rng):
    """Perf guard for the lazy-cache rewrite (measured ≥3× on idle
    hardware; asserted looser here to survive noisy CI boxes)."""
    import time
    n = 512
    a = rng.uniform(0.1, 5.0, (n, n))
    d = 0.5 * (a + a.T)
    np.fill_diagonal(d, 0.0)

    def best_of(fn, reps=2):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(d, 8, linkage="ward")
            best = min(best, time.perf_counter() - t0)
        return best

    t_new = best_of(agglomerate)
    t_old = best_of(_agglomerate_naive)
    assert np.array_equal(agglomerate(d, 8), _agglomerate_naive(d, 8))
    assert t_old / t_new > 1.5, (t_old, t_new)


# ---------------------------------------------------------------------------
# Eq. 9 distance
# ---------------------------------------------------------------------------


def test_pairwise_arccos_properties(rng):
    x = jnp.asarray(rng.normal(size=(9, 16)))
    d = np.asarray(pairwise_arccos(x))
    assert np.allclose(d, d.T, atol=1e-5)
    assert np.allclose(np.diag(d), 0.0)
    assert np.all(d >= 0) and np.all(d <= np.pi + 1e-6)
    # identical direction -> 0; opposite -> pi
    y = jnp.asarray(np.stack([np.ones(8), np.ones(8), -np.ones(8)]))
    dy = np.asarray(pairwise_arccos(y))
    assert dy[0, 1] < 1e-2
    assert dy[0, 2] > np.pi - 1e-2


def test_distance_matrix_lambda_term(rng):
    """λ|ΔĤ| separates same-direction updates of different entropy."""
    base = rng.normal(size=16)
    x = jnp.asarray(np.stack([base * 100.0, base * 100.0, base * 0.001]))
    d0 = np.asarray(distance_matrix(x, temperature=0.01, lam=0.0))
    d10 = np.asarray(distance_matrix(x, temperature=0.01, lam=10.0))
    # angle part identical (same direction): rows 0,1 stay close
    assert d10[0, 1] == pytest.approx(d0[0, 1], abs=1e-4)
    # row 2 has near-uniform softmax (tiny magnitudes) => different Ĥ
    assert d10[0, 2] > d0[0, 2] + 1.0


def test_hics_clusters_split_by_heterogeneity(rng):
    """End-to-end §3.3 claim: with λ=10, balanced clients form their own
    cluster even when directions are noisy."""
    C = 10
    imb = []
    for i in range(8):
        d = np.zeros(C)
        d[i % C] = 1.0
        imb.append(0.05 * (d - 0.1) + rng.normal(0, 1e-4, C))
    bal = [rng.normal(0, 1e-4, C) for _ in range(4)]
    x = jnp.asarray(np.stack(imb + bal))
    dist = np.asarray(distance_matrix(x, temperature=0.0025, lam=10.0))
    # at M=2 the dominant λ|ΔĤ| gap forces the balanced/imbalanced split
    labels = agglomerate(dist, 2, linkage="ward")
    assert len(set(labels[8:])) == 1
    assert len(set(labels[:8])) == 1
    assert labels[0] != labels[-1]
    # and with λ=0 (plain Clustered Sampling) the split is NOT recovered:
    # one-hot directions are mutually ~orthogonal, so the 2-partition mixes
    dist0 = np.asarray(distance_matrix(x, temperature=0.0025, lam=0.0))
    labels0 = agglomerate(dist0, 2, linkage="ward")
    mixed = (len(set(labels0[8:])) > 1) or (len(set(labels0[:8])) > 1)
    assert mixed, "without the entropy term CS should fail to separate"
