"""FL runtime: partitioning, LocalUpdate variants, server integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import label_entropy
from repro.data import SyntheticSpec, make_classification_data, pad_and_stack
from repro.fed import (ALGOS, ExperimentSpec, LocalSpec, build,
                       dirichlet_partition, init_extra, make_local_update,
                       multi_alpha_partition, rounds_to_accuracy,
                       run_experiment)
from repro.models.classifier import make_classifier_with_features
from repro.configs import get_config

# ---------------------------------------------------------------------------
# Partitioning (App. A.10)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=15)
@given(st.integers(2, 20), st.floats(0.005, 50.0),
       st.integers(0, 2**31 - 1))
def test_dirichlet_partition_is_a_partition(n_clients, alpha, seed):
    r = np.random.default_rng(seed)
    labels = r.integers(0, 5, 600)
    parts = dirichlet_partition(r, labels, n_clients, alpha,
                                min_per_client=0)
    allidx = np.concatenate(parts)
    # every sample assigned exactly once
    assert sorted(allidx) == list(range(600))


def test_small_alpha_is_more_imbalanced(rng):
    labels = rng.integers(0, 10, 20_000)
    sharp = dirichlet_partition(rng, labels, 20, 0.001)
    flat = dirichlet_partition(rng, labels, 20, 100.0)

    def mean_entropy(parts):
        es = []
        for p in parts:
            d = np.bincount(labels[p], minlength=10).astype(float)
            es.append(float(label_entropy(jnp.asarray(d / d.sum()))))
        return np.mean(es)

    assert mean_entropy(sharp) < mean_entropy(flat) - 1.0


def test_multi_alpha_groups(rng):
    labels = rng.integers(0, 10, 10_000)
    parts, client_alpha = multi_alpha_partition(
        rng, labels, 50, (0.001, 0.002, 0.005, 0.01, 0.5))
    assert len(parts) == 50
    assert len(np.unique(client_alpha)) == 5
    # each alpha group has 10 clients
    for a in (0.001, 0.5):
        assert (client_alpha == a).sum() == 10
    # the min_per_client top-up steals from the largest clients, so the
    # result is a TRUE partition: complete and duplication-free
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == 10_000                     # full coverage
    assert len(np.unique(allidx)) == 10_000          # disjoint


# ---------------------------------------------------------------------------
# LocalUpdate (client.py)
# ---------------------------------------------------------------------------


def _tiny_problem(rng, n=128):
    spec = SyntheticSpec(num_classes=4, dim=16, rank=2)
    x, y, _ = make_classification_data(rng, spec, n)
    return x, y, spec


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("opt", ["sgd", "momentum", "adam"])
def test_local_update_reduces_loss(rng, algo, opt):
    x, y, spec = _tiny_problem(rng)
    cfg = get_config("paper-mlp")
    init, apply, features = make_classifier_with_features(
        cfg, input_dim=spec.dim)
    params = init(jax.random.PRNGKey(0))
    lspec = LocalSpec(algo=algo, optimizer=opt, lr=0.05, epochs=3,
                      batch_size=32, mu=0.01)
    lu = make_local_update(apply, lspec, features)
    extra = init_extra(lspec, params)
    mask = jnp.ones(len(y))
    new_params, new_extra, metrics = lu(params, extra,
                                        jnp.asarray(x), jnp.asarray(y),
                                        mask, jax.random.PRNGKey(1))
    assert float(metrics["final_loss"]) < float(metrics["train_loss"]) + 0.5
    # params actually moved
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(new_params),
        jax.tree_util.tree_leaves(params)))
    assert moved > 0
    assert np.isfinite(float(metrics["final_loss"]))


def test_fedprox_stays_closer_to_global(rng):
    """Larger μ ⇒ smaller drift from the global model (Eq. 67)."""
    x, y, spec = _tiny_problem(rng, n=256)
    cfg = get_config("paper-mlp")
    init, apply, feats = make_classifier_with_features(cfg,
                                                       input_dim=spec.dim)
    params = init(jax.random.PRNGKey(0))
    mask = jnp.ones(len(y))

    def drift(mu):
        lspec = LocalSpec(algo="fedprox", optimizer="sgd", lr=0.05,
                          epochs=3, batch_size=32, mu=mu)
        lu = make_local_update(apply, lspec)
        p1, _, _ = lu(params, {}, jnp.asarray(x), jnp.asarray(y), mask,
                      jax.random.PRNGKey(1))
        return sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
            jax.tree_util.tree_leaves(p1),
            jax.tree_util.tree_leaves(params)))

    assert drift(10.0) < drift(0.0)


def test_feddyn_state_updates(rng):
    x, y, spec = _tiny_problem(rng)
    cfg = get_config("paper-mlp")
    init, apply, _ = make_classifier_with_features(cfg, input_dim=spec.dim)
    params = init(jax.random.PRNGKey(0))
    lspec = LocalSpec(algo="feddyn", optimizer="sgd", lr=0.05, epochs=2,
                      batch_size=32, mu=0.1)
    lu = make_local_update(apply, lspec)
    extra = init_extra(lspec, params)
    _, new_extra, _ = lu(params, extra, jnp.asarray(x), jnp.asarray(y),
                         jnp.ones(len(y)), jax.random.PRNGKey(1))
    h_norm = sum(float(jnp.abs(l).sum()) for l in
                 jax.tree_util.tree_leaves(new_extra["h"]))
    assert h_norm > 0  # h_k ← h_k − μ(θ_k − θ^t) must move off zero


def test_padded_rows_are_inert(rng):
    """A fully-masked tail must not change the resulting update."""
    x, y, spec = _tiny_problem(rng, n=64)
    cfg = get_config("paper-mlp")
    init, apply, _ = make_classifier_with_features(cfg, input_dim=spec.dim)
    params = init(jax.random.PRNGKey(0))
    lspec = LocalSpec(algo="fedavg", optimizer="sgd", lr=0.05, epochs=1,
                      batch_size=64)
    lu = make_local_update(apply, lspec)
    p1, _, _ = lu(params, {}, jnp.asarray(x), jnp.asarray(y),
                  jnp.ones(64), jax.random.PRNGKey(7))
    xpad = jnp.concatenate([jnp.asarray(x), jnp.zeros((64, spec.dim))])
    ypad = jnp.concatenate([jnp.asarray(y), jnp.zeros(64, jnp.int32)])
    mpad = jnp.concatenate([jnp.ones(64), jnp.zeros(64)])
    p2, _, _ = lu(params, {}, xpad, ypad, mpad, jax.random.PRNGKey(7))
    # same data, same seed, padding only -> identical first-epoch batches
    # are not guaranteed (permutation over 128), but the loss landscape
    # contribution of masked rows must be exactly zero:
    # check gradients directly instead
    lu1 = make_local_update(apply, dataclasses.replace(lspec, epochs=1,
                                                       batch_size=128))
    p3, _, m3 = lu1(params, {}, xpad, ypad, mpad, jax.random.PRNGKey(3))
    assert np.isfinite(float(m3["final_loss"]))
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(p3), jax.tree_util.tree_leaves(params)))
    assert moved > 0


# ---------------------------------------------------------------------------
# Server integration
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("selector", ["random", "hics", "pow-d"])
def test_server_round_loop(selector):
    spec = ExperimentSpec(
        arch="paper-mlp", num_clients=8, num_select=2, rounds=12,
        alphas=(0.05, 5.0), selector=selector,
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                        epochs=2, batch_size=32),
        samples_train=600, samples_test=200, eval_every=2, seed=0)
    hist = run_experiment(spec)
    assert len(hist["round"]) == 12
    assert all(len(s) == 2 for s in hist["selected"])
    assert np.isfinite(hist["train_loss"]).all()
    assert len(hist["test_acc"]) >= 3
    assert hist["test_acc"][-1] > 0.14     # moving off chance (C=10)


def test_server_learns_with_hics():
    spec = ExperimentSpec(
        arch="paper-mlp", num_clients=10, num_select=3, rounds=15,
        alphas=(0.05, 5.0), selector="hics",
        selector_kw={"temperature": 0.0025, "gamma0": 4.0},
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                        epochs=2, batch_size=32),
        samples_train=1500, samples_test=400, eval_every=5, seed=1)
    hist = run_experiment(spec)
    assert hist["test_acc"][-1] > hist["test_acc"][0] + 0.15
    # bias-entropy estimates become available after the sweep
    assert hist["bias_entropy"][-1] is not None


def test_rounds_to_accuracy_helper():
    hist = {"test_round": [0, 5, 10], "test_acc": [0.1, 0.5, 0.9]}
    assert rounds_to_accuracy(hist, 0.5) == 5
    assert rounds_to_accuracy(hist, 0.95) is None


def test_server_zero_retrace_after_round0():
    """lr decay is a traced argument: the vmapped cohort step must
    trace exactly once across 25 rounds (two decay boundaries)."""
    spec = ExperimentSpec(
        arch="paper-mlp", num_clients=6, num_select=2, rounds=25,
        alphas=(0.05, 5.0), selector="random",
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                        epochs=1, batch_size=32),
        samples_train=400, samples_test=100, eval_every=50, seed=0)
    server, _ = build(spec)
    traces = []
    lu = server._lu

    def counting(*args):
        traces.append(1)
        return lu(*args)

    server._lu_vmapped = jax.jit(jax.vmap(
        counting, in_axes=(None, 0, 0, 0, 0, 0, None)))
    server.run()
    assert len(traces) == 1, f"cohort step traced {len(traces)} times"


def test_lr_scale_equals_baked_lr():
    """local_update(lr_scale=s) must match a spec with lr *= s."""
    rng = np.random.default_rng(3)
    x, y, spec = _tiny_problem(rng)
    cfg = get_config("paper-mlp")
    init, apply, _ = make_classifier_with_features(cfg,
                                                   input_dim=spec.dim)
    params = init(jax.random.PRNGKey(0))
    mask = jnp.ones(len(y))
    base = LocalSpec(algo="fedavg", optimizer="sgd", lr=0.08, epochs=2,
                     batch_size=32)
    lu = make_local_update(apply, base)
    p_scaled, _, _ = lu(params, {}, jnp.asarray(x), jnp.asarray(y), mask,
                        jax.random.PRNGKey(1), 0.5)
    lu_baked = make_local_update(
        apply, dataclasses.replace(base, lr=0.08 * 0.5))
    p_baked, _, _ = lu_baked(params, {}, jnp.asarray(x), jnp.asarray(y),
                             mask, jax.random.PRNGKey(1))
    for a, b in zip(jax.tree_util.tree_leaves(p_scaled),
                    jax.tree_util.tree_leaves(p_baked)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


def test_head_bias_updates_stacked_matches_per_client():
    from repro.core import head_bias_update, head_bias_updates_stacked
    rng = np.random.default_rng(5)
    k, d, c = 4, 6, 10
    before = {"body": {"w": jnp.asarray(rng.normal(size=(d, d)))},
              "lm_head": {"w": jnp.asarray(rng.normal(size=(d, c))),
                          "b": jnp.asarray(rng.normal(size=(c,)))}}
    stacked = jax.tree_util.tree_map(
        lambda a: jnp.asarray(
            rng.normal(size=(k,) + a.shape)), before)
    got = head_bias_updates_stacked(before, stacked)
    for i in range(k):
        pk = jax.tree_util.tree_map(lambda a: a[i], stacked)
        want = head_bias_update(before, pk)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want),
                                   atol=1e-6)
    # bias-free head falls back to the ΔW surrogate
    before_nb = {"lm_head": {"w": before["lm_head"]["w"]}}
    stacked_nb = {"lm_head": {"w": stacked["lm_head"]["w"]}}
    got_nb = head_bias_updates_stacked(before_nb, stacked_nb)
    assert got_nb.shape == (k, c)
    for i in range(k):
        pk = jax.tree_util.tree_map(lambda a: a[i], stacked_nb)
        want = head_bias_update(before_nb, pk)
        np.testing.assert_allclose(np.asarray(got_nb[i]),
                                   np.asarray(want), atol=1e-6)
    # no head at all -> None
    assert head_bias_updates_stacked({"x": jnp.zeros(3)},
                                     {"x": jnp.zeros((2, 3))}) is None
