"""Full-update selector (CS / DivFL) battery.

Mirrors tests/test_incremental_selection.py for the generalized strip
kernel and the two full-update baselines:

* epilogue parity — the cosine/L2 strip epilogues against dense
  from-scratch construction, as a hypothesis property sweep over
  random shapes and replacement index sets (duplicates, K = 0, K = N,
  bf16 operands) on both backends;
* selector parity — incremental (cached K-row) vs from-scratch cs /
  divfl triples pick identical participant sets from one key chain;
* driver parity — 30-round scan-vs-host and sweep-vs-host participant
  sets for both selectors (single compile asserted for the scan);
* the down-projection knob — bounded feature buffers that stay
  driver-consistent, plus the OO shim's projection-aware lazy growth.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.core import Observations, make_functional, make_selector
from repro.data import SyntheticSpec
from repro.fed import ExperimentSpec, LocalSpec, build
from repro.kernels import cached_feature_step, gram_row_update
from repro.kernels import ref
from repro.scenarios import SweepSpec, build_pair, run_host_reference


def _scratch_matrix(x, metric):
    """Dense from-scratch distance the selectors historically built."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    if metric == "cosine":
        unit = x / jnp.clip(jnp.linalg.norm(x, axis=-1, keepdims=True),
                            1e-8, None)
        d = jnp.arccos(jnp.clip(unit @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7))
    else:
        sq = jnp.sum(x * x, axis=1)
        d = jnp.sqrt(jnp.clip(
            sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0, None))
    return jnp.where(jnp.eye(n, dtype=bool), 0.0, d)


def _fresh_cache(x, metric, use_pallas=False):
    n = x.shape[0]
    return cached_feature_step(
        x, jnp.zeros((n, n)), jnp.zeros((n, 2)),
        jnp.arange(n, dtype=jnp.int32), metric=metric,
        use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# strip-epilogue property tests: cached == from-scratch, both backends
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(st.integers(4, 32), st.integers(2, 40), st.integers(0, 40),
       st.sampled_from(["cosine", "l2"]), st.integers(0, 2**31 - 1))
def test_cached_feature_step_matches_scratch(n, f, k, metric, seed):
    """Random (N, F, K) and random replacement index sets — duplicates
    included, K clipped into [0, N] — leave the cached matrix within fp
    tolerance of the dense from-scratch build, exactly symmetric with a
    zero diagonal, over two successive replacement rounds."""
    k = min(k, n)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(n, f)) * 0.05, jnp.float32)
    dist, stats = _fresh_cache(x, metric)
    for _ in range(2):
        ids = jnp.asarray(r.integers(0, n, size=k), jnp.int32)
        x = x.at[ids].set(
            jnp.asarray(r.normal(size=(k, f)) * 0.05, jnp.float32))
        dist, stats = cached_feature_step(x, dist, stats, ids,
                                          metric=metric,
                                          use_pallas=False)
    np.testing.assert_allclose(np.asarray(dist),
                               np.asarray(_scratch_matrix(x, metric)),
                               atol=1e-5)
    d = np.asarray(dist)
    np.testing.assert_array_equal(d, d.T)          # exactly symmetric
    np.testing.assert_array_equal(np.diag(d), 0.0)
    np.testing.assert_allclose(
        np.asarray(stats[:, 0]),
        np.asarray(jnp.linalg.norm(x, axis=-1)), atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(st.integers(4, 24), st.integers(2, 30),
       st.sampled_from(["arccos", "cosine", "l2"]),
       st.integers(0, 2**31 - 1))
def test_distance_strip_ref_epilogues(n, f, epilogue, seed):
    """The generalized ref strip reproduces each epilogue's dense
    formula row-for-row (arccos keeps the λ|ΔĤ| term of Eq. 9)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(n, f)) * 0.05, jnp.float32)
    h = jnp.asarray(r.random(n), jnp.float32)
    stats = jnp.stack([jnp.linalg.norm(x, axis=-1), h], axis=-1)
    ids = jnp.asarray(r.integers(0, n, size=min(5, n)), jnp.int32)
    lam = 3.0
    strip = ref.distance_strip_ref(x, stats, ids, lam,
                                   epilogue=epilogue)
    if epilogue == "arccos":
        want = (_scratch_matrix(x, "cosine")
                + lam * jnp.abs(h[:, None] - h[None, :]))
        want = jnp.where(jnp.eye(n, dtype=bool), 0.0, want)
    else:
        want = _scratch_matrix(x, epilogue)
    np.testing.assert_allclose(np.asarray(strip),
                               np.asarray(want[ids]), atol=1e-5)


def test_k_equals_zero_returns_cache_unchanged(rng):
    x = jnp.asarray(rng.normal(size=(10, 6)), jnp.float32)
    dist, stats = _fresh_cache(x, "l2")
    d2, s2 = cached_feature_step(x, dist, stats,
                                 jnp.zeros(0, jnp.int32), metric="l2")
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(stats))


def test_duplicate_ids_are_harmless(rng):
    x0 = jnp.asarray(rng.normal(size=(12, 5)), jnp.float32)
    for metric in ("cosine", "l2"):
        dist, stats = _fresh_cache(x0, metric)
        dup = jnp.asarray([3, 7, 3, 3], jnp.int32)
        x1 = x0.at[dup].set(jnp.asarray(rng.normal(size=(4, 5)),
                                        jnp.float32))
        d, _ = cached_feature_step(x1, dist, stats, dup, metric=metric)
        np.testing.assert_allclose(
            np.asarray(d), np.asarray(_scratch_matrix(x1, metric)),
            atol=1e-5)


@pytest.mark.parametrize("metric", ["cosine", "l2"])
@pytest.mark.parametrize("gram_in_bf16", [False, True])
def test_pallas_cached_matches_ref(rng, metric, gram_in_bf16):
    """Kernel path (interpret mode), f32 and bf16-Gram variants."""
    n, f, k = 20, 260, 6
    x0 = jnp.asarray(rng.normal(size=(n, f)) * 0.05, jnp.float32)
    dist, stats = _fresh_cache(x0, metric, use_pallas=True)
    ids = jnp.asarray(rng.integers(0, n, size=k), jnp.int32)
    x1 = x0.at[ids].set(jnp.asarray(rng.normal(size=(k, f)) * 0.05,
                                    jnp.float32))
    d_p, s_p = cached_feature_step(x1, dist, stats, ids, metric=metric,
                                   gram_in_bf16=gram_in_bf16,
                                   use_pallas=True)
    d_r, s_r = cached_feature_step(x1, *_fresh_cache(x0, metric), ids,
                                   metric=metric, use_pallas=False)
    tol = 1e-4 if not gram_in_bf16 else 3e-2
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_r),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r),
                               atol=1e-4)
    a = np.asarray(d_p)
    np.testing.assert_array_equal(a, a.T)


@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_gram_row_update_epilogue_strip(rng, metric):
    """The raw strip op with an explicit epilogue equals the rows the
    cached step writes, on both backends."""
    n, f, k = 15, 33, 5
    x = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    dist, stats = _fresh_cache(x, metric)
    ids = jnp.asarray(rng.choice(n, size=k, replace=False), jnp.int32)
    strip = gram_row_update(x, stats, ids, lam=0.0, epilogue=metric,
                            use_pallas=False)
    np.testing.assert_allclose(np.asarray(strip),
                               np.asarray(dist[ids]), atol=1e-6)
    strip_p = gram_row_update(x, stats, ids, lam=0.0, epilogue=metric,
                              use_pallas=True)
    np.testing.assert_allclose(np.asarray(strip_p), np.asarray(strip),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# selector-level parity: incremental triple == from-scratch triple
# ---------------------------------------------------------------------------


def _drive(fn, t_max, c, seed, full_rows):
    r = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    state = fn.init(k0)
    picks = []
    for t in range(t_max):
        key, kt = jax.random.split(key)
        ids, state = fn.select(state, t, kt)
        picks.append(np.asarray(ids).tolist())
        rows = ids.shape[0] if full_rows == "sel" else full_rows
        obs = Observations(full_updates=jnp.asarray(
            r.normal(size=(rows, c)) * 0.05, jnp.float32))
        state = fn.update(state, t, ids, obs)
    return picks, state


@settings(deadline=None, max_examples=8)
@given(st.integers(6, 20), st.integers(1, 5), st.integers(2, 12),
       st.integers(0, 2**31 - 1))
def test_cs_incremental_parity_shape_sweep(n, k, c, seed):
    k = min(k, n)
    kw = dict(num_clients=n, num_select=k, total_rounds=12, feat_dim=c)
    fn_inc = make_functional("cs", incremental=True, **kw)
    fn_full = make_functional("cs", incremental=False, **kw)
    p_inc, s_inc = _drive(fn_inc, 12, c, seed % 9973, "sel")
    p_full, _ = _drive(fn_full, 12, c, seed % 9973, "sel")
    assert p_inc == p_full
    assert s_inc.dist_cache.shape == (n, n)
    assert s_inc.row_stats.shape == (n, 2)
    assert s_inc.stale_ids.shape == (k,)


@settings(deadline=None, max_examples=8)
@given(st.integers(6, 20), st.integers(1, 5), st.integers(2, 12),
       st.integers(0, 2**31 - 1))
def test_divfl_selected_incremental_parity_shape_sweep(n, k, c, seed):
    k = min(k, n)
    kw = dict(num_clients=n, num_select=k, total_rounds=12, feat_dim=c,
              refresh="selected")
    fn_inc = make_functional("divfl", incremental=True, **kw)
    fn_full = make_functional("divfl", incremental=False, **kw)
    p_inc, s_inc = _drive(fn_inc, 12, c, seed % 9973, "sel")
    p_full, _ = _drive(fn_full, 12, c, seed % 9973, "sel")
    assert p_inc == p_full
    assert s_inc.dist_cache.shape == (n, n)


def test_divfl_all_ignores_incremental():
    """The ideal setting replaces every feature row per round, so the
    K-row cache cannot help — the factory drops it silently and the
    state carries no cache memory."""
    fn = make_functional("divfl", num_clients=8, num_select=2,
                         total_rounds=5, feat_dim=4, refresh="all",
                         incremental=True)
    state = fn.init(jax.random.PRNGKey(0))
    assert state.dist_cache.shape == (8, 0)
    assert state.stale_ids.shape == (0,)
    assert "full_all" in fn.requires


def test_divfl_refresh_selected_switches_requires():
    fn = make_functional("divfl", num_clients=8, num_select=2,
                         total_rounds=5, refresh="selected")
    assert fn.requires == frozenset({"full_sel"})
    with pytest.raises(ValueError, match="refresh"):
        make_functional("divfl", num_clients=8, num_select=2,
                        total_rounds=5, refresh="bogus")


# ---------------------------------------------------------------------------
# down-projection knob
# ---------------------------------------------------------------------------


def test_projection_bounds_feature_buffer():
    fn = make_functional("cs", num_clients=6, num_select=2,
                         total_rounds=4, feat_dim=1000, proj_dim=32)
    state = fn.init(jax.random.PRNGKey(0))
    assert state.feats.shape == (6, 32)
    assert fn.feat_width(1000) == 32
    assert fn.feat_width(16) == 16          # never widens


def test_projection_preserves_geometry_approximately(rng):
    """Feature hashing is linear, so ‖h(u) − h(v)‖² is an unbiased
    estimate of ‖u − v‖² — every pairwise squared distance survives an
    8× compression within a small relative error (the property the
    L2/cosine clustering actually consumes)."""
    fn = make_functional("cs", num_clients=4, num_select=4,
                         total_rounds=4, feat_dim=4096, proj_dim=512)
    # reach the projector through a driven update (the public surface)
    state = fn.init(jax.random.PRNGKey(0))
    u = rng.normal(size=(32, 4096)).astype(np.float32)
    ids = jnp.arange(4, dtype=jnp.int32)
    projected = []
    for i in range(0, 32, 4):
        s = fn.update(state, 0, ids,
                      Observations(full_updates=jnp.asarray(u[i:i + 4])))
        projected.append(np.asarray(s.feats[:4]))
    h = np.concatenate(projected, axis=0)               # (32, 512)

    def sqd(a):
        s = np.sum(a * a, axis=1)
        return s[:, None] + s[None, :] - 2.0 * (a @ a.T)

    iu = np.triu_indices(32, 1)
    rel = np.abs(sqd(h)[iu] - sqd(u)[iu]) / sqd(u)[iu]
    assert rel.max() < 0.4, rel.max()


def test_shim_grows_projected_width(rng):
    """OO shim standalone: lazy feats growth sizes the buffer through
    fn.feat_width, then update projects the raw rows into it."""
    sel = make_selector("cs", num_clients=6, num_select=2,
                        total_rounds=6, seed=0, proj_dim=16)
    ids = sel.select(0)
    sel.update(0, ids, full_updates=rng.normal(size=(2, 200)))
    assert sel.state.feats.shape == (6, 16)
    # a second cohort keeps the same width (no retrace churn)
    ids = sel.select(1)
    sel.update(1, ids, full_updates=rng.normal(size=(2, 200)))
    assert sel.state.feats.shape == (6, 16)


def test_shim_rejects_double_update_without_select(rng):
    """The generalized staleness hazard: cs's cache is staled by
    full-update observations, so two updates without an intervening
    select fail fast exactly like incremental hics."""
    sel = make_selector("cs", num_clients=8, num_select=2,
                        total_rounds=6, seed=0, feat_dim=4)
    ids = sel.select(0)
    sel.update(0, ids, full_updates=rng.normal(size=(2, 4)))
    with pytest.raises(RuntimeError, match="intervening select"):
        sel.update(0, ids, full_updates=rng.normal(size=(2, 4)))
    sel.select(1)
    sel.update(1, ids, full_updates=rng.normal(size=(2, 4)))


# ---------------------------------------------------------------------------
# 30-round host / scanned / sweep driver parity
# ---------------------------------------------------------------------------

ROUNDS = 30


def _spec(selector, selector_kw, jit_rounds):
    return ExperimentSpec(
        arch="paper-mlp", num_clients=10, num_select=3, rounds=ROUNDS,
        alphas=(0.05, 5.0), selector=selector, selector_kw=selector_kw,
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                        epochs=1, batch_size=32),
        samples_train=400, samples_test=120, eval_every=10 ** 6,
        seed=0, jit_rounds=jit_rounds)


# DivFL's ideal setting polls a one-step gradient from every client
# and greedily maximizes facility-location gains over their pairwise
# distances.  Once training converges those gradients are near-
# duplicates, so the argmax rides on near-exact ties — and the host
# loop's standalone-jitted gradient poll vs the same poll fused into a
# scanned/vmapped program differ by ulps that used to flip such ties.
# The selector now quantizes marginal gains (``tie_quant``, relative to
# the round's max |gain|) before the argmax, so ulp-level noise
# collapses into exact ties broken lexicographically by client id —
# host-vs-device parity holds over the full 30-round horizon.
_DIVFL_ALL_HORIZON = ROUNDS


@pytest.mark.parametrize("selector,kw,horizon", [
    ("cs", None, ROUNDS),
    ("cs", {"incremental": False}, ROUNDS),
    ("divfl", None, _DIVFL_ALL_HORIZON),
    ("divfl", {"refresh": "selected"}, ROUNDS),
    ("cs", {"proj_dim": 64}, ROUNDS),
])
def test_scan_vs_host_30_round_parity(selector, kw, horizon):
    """Acceptance: 30 scanned rounds of each full-update variant equal
    the host loop round-for-round on one key chain, and the scanned
    round_step traces exactly once."""
    host, _ = build(_spec(selector, kw, False))
    h_host = host.run()
    server, _ = build(_spec(selector, kw, True))
    traces = []
    step = server._make_round_step()

    def counting(carry, xs):
        traces.append(1)
        return step(carry, xs)

    server._round_step = counting
    h_scan = server.run()
    assert len(h_host["selected"]) == ROUNDS
    assert h_scan["selected"][:horizon] == h_host["selected"][:horizon]
    assert len(traces) == 1, f"round_step traced {len(traces)} times"
    np.testing.assert_allclose(h_scan["train_loss"][:horizon],
                               h_host["train_loss"][:horizon], atol=1e-5)


SWEEP = SweepSpec(
    scenarios=("dir_mild",), seeds=(0, 1),
    num_clients=10, num_select=3, rounds=ROUNDS,
    samples_train=400, samples_test=120,
    data=SyntheticSpec(dim=16, rank=2, noise=0.5),
    local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1, epochs=1,
                    batch_size=32))


@pytest.mark.parametrize("selector,kw,horizon", [
    ("cs", None, ROUNDS),
    ("divfl", None, _DIVFL_ALL_HORIZON),
    ("divfl", {"refresh": "selected"}, ROUNDS),
])
def test_sweep_vs_host_30_round_parity(selector, kw, horizon):
    """The vmapped sweep engine reproduces the FederatedServer host
    loop seed-for-seed for the full-update selectors — features,
    distance caches and (for divfl) the all-clients gradient poll all
    ride the seed axis.  The vmapped and serial engines must agree
    EXACTLY over all 30 rounds; host parity uses the variant's horizon
    (see _DIVFL_ALL_HORIZON)."""
    spec = dataclasses.replace(SWEEP, selectors=(selector,),
                               selector_kw=kw)
    pair = build_pair(spec, "dir_mild", selector)
    out = pair.vmapped()(pair.params0, pair.sstate0, pair.parts,
                         pair.round_keys)
    serial0 = pair.serial()(*pair.seed_slice(0))
    np.testing.assert_array_equal(np.asarray(out["selected"][0]),
                                  np.asarray(serial0["selected"]))
    for i, seed in enumerate(spec.seeds):
        host = run_host_reference(spec, "dir_mild", selector, int(seed))
        assert host["selected"][:horizon] == \
            np.asarray(out["selected"][i]).tolist()[:horizon], \
            (selector, seed)
    if horizon < ROUNDS:
        # the truncated host horizon is justified by the claim that the
        # scanned server and the sweep engine stay MUTUALLY exact past
        # it — pin that claim over the full 30 rounds
        scan = run_host_reference(spec, "dir_mild", selector,
                                  int(spec.seeds[0]), jit_rounds=True)
        assert scan["selected"] == \
            np.asarray(out["selected"][0]).tolist(), selector


def test_masked_sweep_full_update_selectors_finite():
    """Availability masking composes with the full-update selectors on
    the sweep engine: dropout scenarios stay NaN-free end-to-end."""
    spec = dataclasses.replace(SWEEP, scenarios=("flaky_severe",),
                               selectors=("cs",), rounds=8)
    pair = build_pair(spec, "flaky_severe", "cs")
    out = pair.vmapped()(pair.params0, pair.sstate0, pair.parts,
                         pair.round_keys)
    assert np.isfinite(np.asarray(out["test_acc"])).all()
