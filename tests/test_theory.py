"""Empirical validation of the paper's analytical claims on REAL
training (not the Eq. 6 forward model): the reproduction's §Repro-Claims
backbone.

  * Eq. 6 — Δb from actual SGD training correlates affinely with the
    client's label distribution
  * Thm 3.3 — Ĥ from real Δb orders clients by true entropy (SGD and
    Adam, FedAvg and FedProx)
  * Assumption 3.1 — the gradient-dissimilarity envelope decreases with
    label entropy (Fig. 5 analogue)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_dirichlet_cohort
from repro.configs import get_config
from repro.core import (dissimilarity_envelope, estimate_entropy,
                        head_bias_update, label_entropy)
from repro.core.hetero import dissimilarity_envelope  # noqa: F811
from repro.data import SyntheticSpec, make_classification_data
from repro.fed import LocalSpec, make_local_update
from repro.models.classifier import make_classifier_with_features

C, DIM = 10, 32


def _cohort_data(rng, dists, samples=120):
    spec = SyntheticSpec(num_classes=C, dim=DIM, rank=2)
    x, y, _ = make_classification_data(rng, spec, 6000)
    xs, ys = [], []
    for d in dists:
        idx = []
        for c in range(C):
            pool = np.flatnonzero(y == c)
            take = int(round(d[c] * samples))
            if take:
                idx.extend(rng.choice(pool, take, replace=True))
        idx = np.asarray(idx)
        xs.append(x[idx])
        ys.append(y[idx])
    return xs, ys


def _train_delta_b(rng, dists, algo="fedavg", opt="sgd", lr=0.05,
                   epochs=2):
    cfg = get_config("paper-mlp")
    init, apply, feats = make_classifier_with_features(cfg, input_dim=DIM)
    params = init(jax.random.PRNGKey(0))
    lspec = LocalSpec(algo=algo, optimizer=opt, lr=lr, epochs=epochs,
                      batch_size=32, mu=0.01)
    lu = jax.jit(make_local_update(apply, lspec, feats))
    xs, ys = _cohort_data(rng, dists)
    smax = max(len(s) for s in xs)
    dbs = []
    for i, (x, y) in enumerate(zip(xs, ys)):
        xp = np.zeros((smax, DIM), np.float32)
        yp = np.zeros(smax, np.int32)
        mp = np.zeros(smax, np.float32)
        xp[: len(x)], yp[: len(y)], mp[: len(y)] = x, y, 1.0
        extra = {"prev": params} if algo == "moon" else {}
        if algo == "feddyn":
            extra["h"] = jax.tree_util.tree_map(jnp.zeros_like, params)
        pk, _, _ = lu(params, extra, jnp.asarray(xp), jnp.asarray(yp),
                      jnp.asarray(mp), jax.random.PRNGKey(100 + i))
        dbs.append(np.asarray(head_bias_update(params, pk)))
    return np.stack(dbs)


def test_eq6_real_sgd_linearity(rng):
    """Real Δb correlates with (D_i − 1/C): per-client Pearson > 0.7."""
    dists, _ = make_dirichlet_cohort(rng, num_clients=12,
                                     alphas=(0.05, 10.0))
    db = _train_delta_b(rng, dists)
    cors = []
    for i in range(len(dists)):
        d_centered = dists[i] - dists[i].mean()
        b_centered = db[i] - db[i].mean()
        denom = np.linalg.norm(d_centered) * np.linalg.norm(b_centered)
        cors.append(float(d_centered @ b_centered / (denom + 1e-12)))
    assert np.mean(cors) > 0.7, cors


@pytest.mark.parametrize("algo,opt", [("fedavg", "sgd"),
                                      ("fedavg", "adam"),
                                      ("fedprox", "sgd"),
                                      ("moon", "sgd")])
def test_thm33_entropy_ordering_real_training(rng, algo, opt):
    """Ĥ(softmax(Δb/T)) from real local training separates balanced from
    imbalanced clients — incl. beyond-SGD optimizers (App. A.8/A.9)."""
    dists, n_imb = make_dirichlet_cohort(rng, num_clients=15,
                                         alphas=(0.02, 20.0))
    lr = 0.01 if opt == "adam" else 0.05
    db = _train_delta_b(rng, dists, algo=algo, opt=opt, lr=lr)
    temp = np.quantile(np.abs(db), 0.9) + 1e-9
    h = np.asarray(estimate_entropy(jnp.asarray(db), float(temp)))
    assert h[n_imb:].mean() > h[:n_imb].mean() + 0.1, \
        (algo, opt, h[:n_imb].mean(), h[n_imb:].mean())


def test_assumption31_envelope(rng):
    """Gradient dissimilarity ‖∇F_k − ∇F‖² decreases with H(D_k) and is
    enveloped by κ − ρ e^{β(H − lnC)} (Fig. 5 / App. A.2 analogue)."""
    dists, _ = make_dirichlet_cohort(rng, num_clients=24,
                                     alphas=(0.05, 20.0))
    cfg = get_config("paper-mlp")
    init, apply, _ = make_classifier_with_features(cfg, input_dim=DIM)
    params = init(jax.random.PRNGKey(0))
    xs, ys = _cohort_data(rng, dists, samples=200)

    def grad_of(x, y):
        def lf(p):
            logits = apply(p, jnp.asarray(x))
            logz = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(
                logits, jnp.asarray(y)[:, None], axis=-1)[..., 0]
            return jnp.mean(logz - tgt)
        g = jax.grad(lf)(params)
        return np.concatenate([np.ravel(t) for t in
                               jax.tree_util.tree_leaves(g)])

    x_all = np.concatenate(xs)
    y_all = np.concatenate(ys)
    g_true = grad_of(x_all, y_all)
    diffs, ents = [], []
    for x, y, d in zip(xs, ys, dists):
        diffs.append(float(np.sum((grad_of(x, y) - g_true) ** 2)))
        ents.append(float(label_entropy(jnp.asarray(d))))
    diffs, ents = np.asarray(diffs), np.asarray(ents)
    # monotone trend: top-entropy third vs bottom third
    order = np.argsort(ents)
    lo = diffs[order[:8]].mean()
    hi = diffs[order[-8:]].mean()
    assert hi < lo, (lo, hi)
    # a (κ, ρ, β) envelope covering >= 90% of points exists
    kappa = diffs.max() * 1.05
    rho = kappa - diffs[order[-8:]].mean() * 0.9
    for beta in (0.5, 1.0, 1.5, 2.0):
        env = dissimilarity_envelope(ents, kappa, rho, beta,
                                     num_classes=C)
        if np.mean(diffs <= env + 1e-9) >= 0.9:
            return
    pytest.fail("no Assumption-3.1 envelope covered 90% of clients")
