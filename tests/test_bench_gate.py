"""tools/bench_gate.py: rule matching, regression detection, tolerance,
and the env-stamp comparability refusal."""
import importlib.util
import json
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "bench_gate", REPO / "tools" / "bench_gate.py")
bg = importlib.util.module_from_spec(_spec)
# dataclasses resolves the module through sys.modules when evaluating
# the (PEP 563) string annotations — register before exec
import sys
sys.modules["bench_gate"] = bg
_spec.loader.exec_module(bg)

ENV_A = {"backend": "cpu", "device_kind": "cpu", "cpu_count": 8}
ENV_B = {"backend": "cpu", "device_kind": "cpu", "cpu_count": 64}


def _selection(speedup, env=ENV_A):
    return {"env": dict(env),
            "results": {"N64_C32768": {"speedup": speedup,
                                       "fused_ms": 1.0}},
            "incremental_vs_full": {"N=64": {"speedup": 5.0}}}


def _dirs(tmp_path, fresh, base):
    fd, bd = tmp_path / "fresh", tmp_path / "base"
    fd.mkdir(), bd.mkdir()
    (fd / "BENCH_selection.json").write_text(json.dumps(fresh))
    (bd / "BENCH_selection.json").write_text(json.dumps(base))
    return fd, bd


def test_flatten_and_match():
    flat = bg.flatten(_selection(1.6))
    assert flat["results.N64_C32768.speedup"] == 1.6
    assert bg.match("results.*.speedup", "results.N64_C32768.speedup")
    assert not bg.match("results.*.speedup",
                        "results.N64_C32768.fused_ms")
    # * is segment-local: never crosses a dot
    assert not bg.match("results.*", "results.N64_C32768.speedup")


def test_within_tolerance_passes(tmp_path):
    fd, bd = _dirs(tmp_path, _selection(1.5), _selection(1.6))  # -6%
    assert bg.run_gate(baseline_dir=bd, fresh_dir=fd) == 0


def test_regression_fails(tmp_path):
    fd, bd = _dirs(tmp_path, _selection(1.2), _selection(1.6))  # -25%
    assert bg.run_gate(baseline_dir=bd, fresh_dir=fd) == 1


def test_improvement_passes(tmp_path):
    fd, bd = _dirs(tmp_path, _selection(3.2), _selection(1.6))  # +100%
    assert bg.run_gate(baseline_dir=bd, fresh_dir=fd) == 0


def test_lower_is_better_direction(tmp_path):
    base = {"env": dict(ENV_A),
            "clustering_scaling": {"N=64": {"device_over_numpy": 1.0}}}
    worse = {"env": dict(ENV_A),
             "clustering_scaling": {"N=64": {"device_over_numpy": 2.5}}}
    fd, bd = _dirs(tmp_path, worse, base)       # +150%, beyond the ±2x
    assert bg.run_gate(baseline_dir=bd, fresh_dir=fd) == 1
    fd2 = tmp_path / "fresh2"
    fd2.mkdir()
    better = {"env": dict(ENV_A),
              "clustering_scaling": {"N=64": {"device_over_numpy": 0.5}}}
    (fd2 / "BENCH_selection.json").write_text(json.dumps(better))
    assert bg.run_gate(baseline_dir=bd, fresh_dir=fd2) == 0


def test_env_mismatch_skips_not_fails(tmp_path, capsys):
    """A 25% regression measured on a different machine is NOT a
    regression — the gate must skip the file and exit 0."""
    fd, bd = _dirs(tmp_path, _selection(1.2, env=ENV_B),
                   _selection(1.6, env=ENV_A))
    assert bg.run_gate(baseline_dir=bd, fresh_dir=fd) == 0
    assert "env mismatch" in capsys.readouterr().out


def test_unstamped_baseline_still_compared(tmp_path):
    """Legacy artifacts without an env stamp gate normally."""
    base = _selection(1.6)
    del base["env"]
    fd, bd = _dirs(tmp_path, _selection(1.2), base)
    assert bg.run_gate(baseline_dir=bd, fresh_dir=fd) == 1


def test_missing_baseline_skips(tmp_path):
    fd = tmp_path / "fresh"
    fd.mkdir()
    (fd / "BENCH_selection.json").write_text(json.dumps(_selection(1.6)))
    bd = tmp_path / "empty"
    bd.mkdir()
    assert bg.run_gate(baseline_dir=bd, fresh_dir=fd) == 0


def test_selftest_on_real_artifacts():
    """The CI acceptance bar: an injected 25% drop in a
    BENCH_selection.json speedup must fail while the committed
    artifacts pass."""
    if not (REPO / "BENCH_selection.json").exists():
        pytest.skip("no committed BENCH_selection.json")
    assert bg.selftest(baseline_dir=None) == 0
