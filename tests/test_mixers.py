"""Numerical oracles for the sequence mixers:

  * Mamba2 chunked SSD vs a naive per-token recurrence
  * chunk-boundary/state-carry invariance
  * sliding-window attention vs a dense masked reference
  * RWKV6 wkv segment/state-carry invariance
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SSMConfig
from repro.models.layers import full_attention
from repro.models.mamba import ssd_chunked


def _naive_ssd(x, a_log_t, Bm, Cm, dt, state):
    """Per-token recurrence: s_t = e^{a_t} s_{t-1} + dt_t x_t⊗B_t;
    y_t = C_t · s_t."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    s = np.asarray(state, np.float64).copy()
    ys = np.zeros((B, T, H, P))
    xf = np.asarray(x, np.float64)
    af = np.asarray(a_log_t, np.float64)
    Bf = np.asarray(Bm, np.float64)
    Cf = np.asarray(Cm, np.float64)
    df = np.asarray(dt, np.float64)
    for t in range(T):
        decay = np.exp(af[:, t])[:, :, None, None]          # (B,H,1,1)
        upd = df[:, t][:, :, None, None] * \
            np.einsum("bhp,bn->bhpn", xf[:, t], Bf[:, t])
        s = decay * s + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cf[:, t], s)
    return ys, s


def _rand_ssd_inputs(rng, B=2, T=16, H=3, P=4, N=5):
    x = jnp.asarray(rng.normal(size=(B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, T, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.05, 1.0, (B, T, H)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, P, N)), jnp.float32)
    return x, a, Bm, Cm, dt, s0


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_recurrence(rng, chunk):
    x, a, Bm, Cm, dt, s0 = _rand_ssd_inputs(rng)
    ssm = SSMConfig(chunk=chunk)
    y, s_final = ssd_chunked(x, a, Bm, Cm, dt, ssm, state=s0)
    y_ref, s_ref = _naive_ssd(x, a, Bm, Cm, dt, s0)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_final), s_ref, atol=1e-4,
                               rtol=1e-4)


def test_ssd_segment_state_carry(rng):
    """Processing [0:8] then [8:16] with the carried state must equal one
    [0:16] pass — the property decode/prefill splits rely on."""
    x, a, Bm, Cm, dt, s0 = _rand_ssd_inputs(rng, T=16)
    ssm = SSMConfig(chunk=8)
    y_full, s_full = ssd_chunked(x, a, Bm, Cm, dt, ssm, state=s0)
    y1, s_mid = ssd_chunked(x[:, :8], a[:, :8], Bm[:, :8], Cm[:, :8],
                            dt[:, :8], ssm, state=s0)
    y2, s_end = ssd_chunked(x[:, 8:], a[:, 8:], Bm[:, 8:], Cm[:, 8:],
                            dt[:, 8:], ssm, state=s_mid)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# sliding-window attention
# ---------------------------------------------------------------------------


def _dense_attention(q, k, v, window, causal=True):
    B, T, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = np.asarray(q, np.float64).reshape(B, T, KV, G, dh)
    kf = np.asarray(k, np.float64)
    vf = np.asarray(v, np.float64)
    s = np.einsum("bqkgd,btkd->bkgqt", qf, kf) / np.sqrt(dh)
    qpos = np.arange(T)[:, None]
    kpos = np.arange(T)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    s = np.where(m[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bkgqt,btkd->bqkgd", p, vf)
    return out.reshape(B, T, H, dh)


@pytest.mark.parametrize("window,q_chunk", [(0, 8), (4, 8), (16, 4),
                                            (4, 32)])
def test_sliding_window_attention(rng, window, q_chunk):
    B, T, H, KV, dh = 2, 32, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, dh)), jnp.float32)
    got = full_attention(q, k, v, causal=True, window=window,
                         q_chunk=q_chunk)
    want = _dense_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4, rtol=1e-4)


def test_window_limits_receptive_field(rng):
    """Perturbing a key outside the window must not change the output."""
    B, T, H, KV, dh, W = 1, 16, 2, 2, 8, 4
    q = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, dh)), jnp.float32)
    out1 = full_attention(q, k, v, causal=True, window=W)
    k2 = k.at[:, 0].add(100.0)     # position 0 is outside t=15's window
    v2 = v.at[:, 0].add(100.0)
    out2 = full_attention(q, k2, v2, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(out1[:, -1]),
                               np.asarray(out2[:, -1]), atol=1e-5)
    # but position 1 DOES see position 0
    assert not np.allclose(np.asarray(out1[:, 1]), np.asarray(out2[:, 1]))


# ---------------------------------------------------------------------------
# RWKV segment carry
# ---------------------------------------------------------------------------


def test_rwkv_forward_segment_carry(rng):
    from repro.configs import get_config
    from repro.models import rwkv as RK
    cfg = get_config("rwkv6-3b").reduced()
    params = RK.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    hfull, _ = RK.forward(params, toks, cfg)
    h1, c1 = RK.forward(params, toks[:, :8], cfg)
    h2, _ = RK.forward(params, toks[:, 8:], cfg, cache=c1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], 1)), np.asarray(hfull),
        atol=2e-3, rtol=2e-3)
