"""Scenario subsystem: partition invariants shared between the host
(numpy) and device (pure-jax) partitioners, the scenario registry, and
the availability machinery."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import label_entropy, make_functional
from repro.fed import dirichlet_partition, multi_alpha_partition
from repro.scenarios import (SCENARIOS, Partition, availability_mask,
                             get_scenario, masked_select,
                             partition_device,
                             partition_label_distributions,
                             replace_unavailable, scenario_key)

C = 10


# ---------------------------------------------------------------------------
# shared partition invariants (satellite: host/device co-tested)
# ---------------------------------------------------------------------------


def _host_invariants(parts, total):
    """A host partition must be disjoint and complete."""
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == total, "not complete"
    assert len(np.unique(allidx)) == total, "not disjoint"


def _device_invariants(part: Partition, total):
    """A device partition must be disjoint and complete up to the cap
    clip, with counts preserving every sample."""
    idx = np.asarray(part.idx)
    mask = np.asarray(part.mask)
    counts = np.asarray(part.counts)
    kept = idx[mask > 0]
    assert counts.sum() == total                      # every sample owned
    assert len(np.unique(kept)) == len(kept)          # disjoint
    assert len(kept) == np.minimum(counts, idx.shape[1]).sum()
    assert (idx >= 0).all() and (idx < total).all()


def _labels(seed, n=4000):
    return np.random.default_rng(seed).integers(0, C, n)


@pytest.mark.parametrize("kind,kw", [
    ("dirichlet", {"alphas": (0.01,)}),
    ("multi_alpha", {"alphas": (0.001, 0.5)}),
    ("shards", {"labels_per_client": 2}),
    ("quantity", {"beta": 0.5}),
    ("iid", {}),
])
def test_device_partition_invariants(kind, kw):
    labels = _labels(0)
    part = partition_device(jax.random.PRNGKey(3), jnp.asarray(labels),
                            C, 16, kind, len(labels), **kw)
    _device_invariants(part, len(labels))
    # with cap == S nothing overflows: fully complete
    assert float(part.mask.sum()) == len(labels)


def test_device_partition_cap_overflow():
    labels = _labels(1, 1000)
    part = partition_device(jax.random.PRNGKey(0), jnp.asarray(labels),
                            C, 8, "quantity", 64, beta=0.3)
    _device_invariants(part, 1000)
    counts = np.asarray(part.counts)
    kept = np.asarray(part.mask).sum(axis=1)
    np.testing.assert_array_equal(kept, np.minimum(counts, 64))


def test_host_partitions_stay_partitions():
    """Satellite regression: the min_per_client top-up steals from the
    largest clients instead of duplicating global indices, so starved
    cohorts still yield a true partition with the floor met."""
    r = np.random.default_rng(0)
    labels = r.integers(0, 5, 60)
    parts = dirichlet_partition(r, labels, 20, 0.001, min_per_client=2)
    _host_invariants(parts, 60)
    assert all(len(p) >= 2 for p in parts)      # feasible: 20·2 ≤ 60

    r = np.random.default_rng(1)
    labels = r.integers(0, C, 10_000)
    parts, client_alpha = multi_alpha_partition(
        r, labels, 50, (0.001, 0.002, 0.005, 0.01, 0.5))
    _host_invariants(parts, 10_000)             # no duplication, ever
    # group coverage: equal client groups, each α represented
    assert len(np.unique(client_alpha)) == 5
    for a in (0.001, 0.5):
        assert (client_alpha == a).sum() == 10


def test_multi_alpha_group_slices_host_and_device():
    """Group structure invariant, co-asserted on both partitioners:
    cohort g's clients own exactly the g-th equal data slice."""
    labels = _labels(2, 3000)
    alphas = (0.001, 0.01, 0.5)
    r = np.random.default_rng(5)
    parts, client_alpha = multi_alpha_partition(r, labels, 12, alphas)
    slice_sizes = [len(a) for a in np.array_split(np.arange(3000), 3)]
    for g, a in enumerate(alphas):
        got = sum(len(parts[k]) for k in range(12)
                  if client_alpha[k] == a)
        assert got == slice_sizes[g]

    part = partition_device(jax.random.PRNGKey(7), jnp.asarray(labels),
                            C, 12, "multi_alpha", 3000, alphas=alphas)
    counts = np.asarray(part.counts)
    groups = np.array_split(np.arange(12), 3)
    for g, cg in enumerate(groups):
        assert counts[cg].sum() == slice_sizes[g]


def test_host_device_entropy_parity():
    """The device multinomial-Dirichlet assignment must match the host
    largest-remainder split in distribution: same per-label totals
    (exact) and the same mean client label-entropy within multinomial
    noise, across concentration regimes."""
    S, N = 6000, 30
    for alpha, tol in ((0.1, 0.15), (1.0, 0.1), (10.0, 0.1)):
        hs, ds = [], []
        for seed in range(3):
            r = np.random.default_rng(seed)
            labels = r.integers(0, C, S)
            parts = dirichlet_partition(r, labels, N, alpha,
                                        min_per_client=0)
            dists = np.zeros((N, C))
            for i, p in enumerate(parts):
                if len(p):
                    dists[i] = np.bincount(labels[p], minlength=C) / len(p)
            hs.append(float(label_entropy(jnp.asarray(dists)).mean()))
            part = partition_device(
                jax.random.PRNGKey(seed), jnp.asarray(labels), C, N,
                "dirichlet", S, alphas=(alpha,))
            d = partition_label_distributions(part, jnp.asarray(labels), C)
            ds.append(float(label_entropy(d).mean()))
            # per-label totals are exact on both sides (completeness)
            y_dev = np.asarray(labels)[np.asarray(part.idx)]
            got = np.bincount(y_dev[np.asarray(part.mask) > 0],
                              minlength=C)
            np.testing.assert_array_equal(
                got, np.bincount(labels, minlength=C))
        assert abs(np.mean(hs) - np.mean(ds)) < tol, (alpha, hs, ds)


def test_device_alpha_ordering():
    labels = _labels(3, 6000)
    ents = {}
    for alpha in (0.01, 100.0):
        part = partition_device(jax.random.PRNGKey(0), jnp.asarray(labels),
                                C, 20, "dirichlet", 6000, alphas=(alpha,))
        d = partition_label_distributions(part, jnp.asarray(labels), C)
        ents[alpha] = float(label_entropy(d).mean())
    assert ents[0.01] < ents[100.0] - 1.0


def test_shards_label_limit():
    labels = _labels(4, 2000)
    L = 2
    part = partition_device(jax.random.PRNGKey(1), jnp.asarray(labels),
                            C, 10, "shards", 2000, labels_per_client=L)
    idx, mask = np.asarray(part.idx), np.asarray(part.mask)
    for k in range(10):
        y = labels[idx[k][mask[k] > 0]]
        # L shards, each straddling ≤ 2 label runs
        assert len(np.unique(y)) <= 2 * L


def test_iid_exactly_balanced():
    part = partition_device(jax.random.PRNGKey(2),
                            jnp.asarray(_labels(5, 1200)), C, 8, "iid",
                            1200)
    np.testing.assert_array_equal(np.asarray(part.counts),
                                  np.full(8, 150))


def test_quantity_skew_sizes():
    labels = _labels(6, 4000)
    iid = partition_device(jax.random.PRNGKey(0), jnp.asarray(labels),
                           C, 16, "iid", 4000)
    qty = partition_device(jax.random.PRNGKey(0), jnp.asarray(labels),
                           C, 16, "quantity", 4000, beta=0.3)
    assert np.asarray(qty.counts).std() > np.asarray(iid.counts).std() + 10
    # labels stay ~IID per client: entropy close to the iid partition's
    ei = float(label_entropy(
        partition_label_distributions(iid, jnp.asarray(labels), C)).mean())
    eq = float(label_entropy(
        partition_label_distributions(qty, jnp.asarray(labels), C)).mean())
    assert eq > ei - 0.35


def test_partition_vmaps_over_keys():
    """The whole point: a stack of keys yields a stack of partitions."""
    labels = jnp.asarray(_labels(7, 500))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(3))
    parts = jax.vmap(lambda k: partition_device(
        k, labels, C, 6, "dirichlet", 500, alphas=(0.1,)))(keys)
    assert parts.idx.shape == (3, 6, 500)
    for i in range(3):
        _device_invariants(jax.tree_util.tree_map(lambda l: l[i], parts),
                           500)
    # different keys → different partitions
    assert not np.array_equal(np.asarray(parts.counts[0]),
                              np.asarray(parts.counts[1]))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_lookup_and_keys():
    scn = get_scenario("mixed_80_20")
    assert scn.kind == "multi_alpha" and len(scn.alphas) == 5
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")
    k1 = scenario_key(scn, 3)
    k2 = scenario_key(scn, 3)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    assert not np.array_equal(
        np.asarray(scenario_key(scn, 4)), np.asarray(k1))
    assert not np.array_equal(
        np.asarray(scenario_key(get_scenario("dir_mild"), 3)),
        np.asarray(k1))
    # every registered scenario partitions cleanly
    labels = jnp.asarray(_labels(8, 600))
    for name, s in SCENARIOS.items():
        part = s.partition(scenario_key(s, 0), labels, C, 6, 600)
        _device_invariants(part, 600)
        assert s.paper, f"{name} missing its paper mapping"


# ---------------------------------------------------------------------------
# availability
# ---------------------------------------------------------------------------


def test_availability_kinds():
    always = get_scenario("dir_mild")
    assert bool(availability_mask(always, 8, 0,
                                  jax.random.PRNGKey(0)).all())
    flaky = get_scenario("flaky_severe")
    masks = [availability_mask(flaky, 200, t, jax.random.PRNGKey(t))
             for t in range(5)]
    frac = np.mean([float(m.mean()) for m in masks])
    assert 0.6 < frac < 0.8                      # p = 0.3 dropout
    assert not np.array_equal(np.asarray(masks[0]), np.asarray(masks[1]))
    blocks = get_scenario("diurnal_mixed")
    m = np.stack([np.asarray(availability_mask(
        blocks, 8, t, jax.random.PRNGKey(0))) for t in range(8)])
    assert m.shape == (8, 8)
    np.testing.assert_array_equal(m[0], m[4])     # period 4
    assert 0 < m.mean() < 1                       # some off, some on


def test_replace_unavailable():
    weights = jnp.ones(10) / 10
    ids = jnp.asarray([0, 1, 2], jnp.int32)
    avail = jnp.ones(10, bool).at[1].set(False)
    out = np.asarray(replace_unavailable(jax.random.PRNGKey(0), ids,
                                         avail, weights))
    assert out[0] == 0 and out[2] == 2
    assert out[1] not in (0, 1, 2) and bool(avail[out[1]])
    # nobody available → picks kept rather than deadlocking
    none = jnp.zeros(10, bool)
    np.testing.assert_array_equal(
        np.asarray(replace_unavailable(jax.random.PRNGKey(1), ids, none,
                                       weights)), np.asarray(ids))


def test_masked_select_respects_mask():
    fn = make_functional("random", num_clients=12, num_select=4,
                         total_rounds=10)
    state = fn.init(jax.random.PRNGKey(0))
    avail = jnp.zeros(12, bool).at[jnp.asarray([2, 5, 7, 9, 11])].set(True)
    for t in range(6):
        key = jax.random.PRNGKey(100 + t)
        ids, state = masked_select(fn, state, t, key, avail,
                                   jax.random.fold_in(key, 1))
        picked = np.asarray(ids)
        assert np.asarray(avail)[picked].all()
        assert len(set(picked.tolist())) == 4
    # weights restored, not persistently masked
    np.testing.assert_allclose(np.asarray(state.weights),
                               np.full(12, 1 / 12), atol=1e-6)


def test_masked_select_keeps_replaced_clients_unseen():
    """An offline client picked by HiCS's coverage sweep and swapped
    out never trained — it must NOT be marked seen (else its all-zero
    Δb row reads as maximal entropy for the rest of the run)."""
    n, k = 8, 3
    fn = make_functional("hics", num_clients=n, num_select=k,
                         total_rounds=10, num_classes=4)
    state = fn.init(jax.random.PRNGKey(0))
    offline = 0
    avail = jnp.ones(n, bool).at[offline].set(False)
    seen_any_offline = False
    for t in range(4):                       # sweep phase: ceil(8/3) rds
        key = jax.random.PRNGKey(50 + t)
        ids, state = masked_select(fn, state, t, key, avail,
                                   jax.random.fold_in(key, 1))
        picked = np.asarray(ids)
        assert offline not in picked
        seen_any_offline |= bool(np.asarray(state.seen)[offline])
        # update marks exactly the actual participants
        from repro.core import Observations
        state = fn.update(state, t, ids, Observations(
            bias_updates=jnp.ones((k, 4)) * 0.01))
    assert not seen_any_offline
    assert int(state.unseen_count) == 1      # only the offline client
    # once it comes back online, the sweep picks it up
    key = jax.random.PRNGKey(99)
    ids, state = masked_select(fn, state, 4, key, jnp.ones(n, bool),
                               jax.random.fold_in(key, 1))
    assert offline in np.asarray(ids)
