"""Buffered-async server acceptance.

The parity oracle: with the identity latency model and capacity =
threshold = K, every tick fires with all ages 0, so the async scan must
reproduce the sync loop BIT-EXACTLY — participant sets, key chain and
parameters — for ≥ 2 selectors (hics + a full-update one) across the
host, scanned-server and vmapped-sweep drivers.  Plus: ring-buffer
invariants (FIFO, counted overflow), latency-table determinism,
staleness-ring cache refresh vs from-scratch recompute under
out-of-order / duplicate / empty cohorts, and ``masked_select`` with
zero available clients.
"""
import dataclasses
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Observations, make_functional
from repro.core.selectors.functional import stale_append
from repro.data import SyntheticSpec
from repro.fed import (AsyncConfig, AsyncFederatedServer, LatencySpec,
                       LocalSpec, buffer_init, buffer_pop, buffer_push,
                       delay_tables)
from repro.fed.latency import KINDS, max_delay
from repro.kernels import hics_selection_step_cached
from repro.scenarios import (SweepSpec, build_async_pair, build_pair,
                             get_scenario, make_dataset, masked_select,
                             materialize, run_async_sweep,
                             run_host_reference)
from repro.scenarios.registry import SCENARIOS
from repro.scenarios.sweep import _make_model

SPEC = SweepSpec(
    scenarios=("dir_mild",), selectors=("hics",), seeds=(0, 1),
    num_clients=8, num_select=2, rounds=6,
    samples_train=160, samples_test=64,
    data=SyntheticSpec(dim=16, rank=2, noise=0.5),
    local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1, epochs=1,
                    batch_size=32))

_PROTO = {"v": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# ring buffer invariants
# ---------------------------------------------------------------------------


def test_buffer_push_pop_fifo():
    buf = buffer_init(3, _PROTO)
    rows = {"v": jnp.asarray([10.0, 20.0, 30.0, 40.0])}
    mask = jnp.asarray([True, False, True, True])
    ids = jnp.arange(4, dtype=jnp.int32)
    ver = jnp.asarray([5, 6, 7, 8], jnp.int32)
    buf, acc, drop = buffer_push(buf, mask, rows, ids, ver)
    assert (int(acc), int(drop), int(buf.fill)) == (3, 0, 3)
    payload, pids, pver, buf = buffer_pop(buf, 2)
    np.testing.assert_array_equal(payload["v"], [10.0, 30.0])
    np.testing.assert_array_equal(pids, [0, 2])
    np.testing.assert_array_equal(pver, [5, 7])
    assert int(buf.fill) == 1 and int(buf.head) == 2


def test_buffer_overflow_counted_not_silent():
    buf = buffer_init(2, _PROTO)
    rows = {"v": jnp.asarray([1.0, 2.0, 3.0, 4.0])}
    mask = jnp.ones(4, bool)
    buf, acc, drop = buffer_push(buf, mask, rows,
                                 jnp.arange(4, dtype=jnp.int32),
                                 jnp.zeros(4, jnp.int32))
    assert (int(acc), int(drop)) == (2, 2)       # accepted + dropped = 4
    payload, _, _, buf = buffer_pop(buf, 2)
    np.testing.assert_array_equal(payload["v"], [1.0, 2.0])  # oldest kept
    assert int(buf.fill) == 0


def test_buffer_wraparound():
    buf = buffer_init(3, _PROTO)
    push = lambda b, vals: buffer_push(
        b, jnp.ones(len(vals), bool),
        {"v": jnp.asarray(vals, jnp.float32)},
        jnp.zeros(len(vals), jnp.int32), jnp.zeros(len(vals), jnp.int32))
    buf, _, _ = push(buf, [1.0, 2.0, 3.0])
    _, _, _, buf = buffer_pop(buf, 2)                 # head wraps past 0
    buf, acc, drop = push(buf, [4.0, 5.0])
    assert (int(acc), int(drop), int(buf.fill)) == (2, 0, 3)
    payload, _, _, buf = buffer_pop(buf, 3)
    np.testing.assert_array_equal(payload["v"], [3.0, 4.0, 5.0])


def test_buffer_init_validates_capacity():
    with pytest.raises(ValueError, match="capacity"):
        buffer_init(0, _PROTO)


# ---------------------------------------------------------------------------
# latency models
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_latency_tables_shapes_and_determinism(kind):
    spec = LatencySpec(kind=kind, seed=3)
    b1, j1 = delay_tables(spec, 12, 9, 4)
    b2, j2 = delay_tables(spec, 12, 9, 4)
    assert b1.shape == (12,) and j1.shape == (9, 4)
    assert b1.dtype == np.int32 and j1.dtype == np.int32
    assert (b1 >= 0).all() and (j1 >= 0).all()
    np.testing.assert_array_equal(b1, b2)       # same seed, same traffic
    np.testing.assert_array_equal(j1, j2)


def test_identity_latency_is_all_zero():
    base, jitter = delay_tables(LatencySpec(), 10, 7, 3)
    assert not base.any() and not jitter.any()


def test_flash_crowd_pattern():
    spec = LatencySpec(kind="flash_crowd", period=4)
    _, jitter = delay_tables(spec, 5, 8, 2)
    for t in range(8):                 # every dispatch of a period lands
        assert (jitter[t] == 4 - 1 - (t % 4)).all()   # on its last tick


def test_latency_kind_validated():
    with pytest.raises(ValueError, match="latency kind"):
        LatencySpec(kind="warp")


def test_max_delay_clipped_to_max_lag():
    spec = LatencySpec(kind="stragglers", straggler_frac=1.0,
                       straggler_delay=100)
    base, jitter = delay_tables(spec, 6, 4, 2)
    assert max_delay(spec, base, jitter, 5) == 5
    idn = LatencySpec()
    b0, j0 = delay_tables(idn, 6, 4, 2)
    assert max_delay(idn, b0, j0, 5) == 0


def test_async_config_threshold_validated():
    with pytest.raises(ValueError, match="threshold"):
        AsyncConfig(num_select=2, capacity=2, threshold=3).sizes()


# ---------------------------------------------------------------------------
# the parity oracle: identity latency + B = M = K  ==  sync, bit-exact
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _sweep_parity(selector):
    sync = build_pair(SPEC, "dir_mild", selector)
    apair, _ = build_async_pair(SPEC, "dir_mild", selector)
    so = jax.tree_util.tree_map(np.asarray, sync.vmapped()(
        sync.params0, sync.sstate0, sync.parts, sync.round_keys))
    ao = jax.tree_util.tree_map(np.asarray, apair.vmapped()(
        apair.params0, apair.sstate0, apair.parts, apair.round_keys))
    return so, ao


@functools.lru_cache(maxsize=None)
def _client_data(seed=0):
    scn = SPEC.scenario("dir_mild")
    cfg = get_config(SPEC.arch)
    train, test, _ = make_dataset(scn, SPEC.samples_train,
                                  SPEC.samples_test, cfg.vocab_size,
                                  SPEC.data_seed)
    part = materialize(scn, seed, train, cfg.vocab_size,
                       SPEC.num_clients, SPEC.capacity())
    init_fn, apply_fn, _ = _make_model(SPEC, cfg, scn.data.dim)
    idx = np.asarray(part.idx)
    return (init_fn, apply_fn, np.asarray(train["x"])[idx],
            np.asarray(train["y"])[idx], np.asarray(part.mask),
            {k: np.asarray(v) for k, v in test.items()})


def _standalone(selector, latency=LatencySpec(), **acfg_kw):
    init_fn, apply_fn, cx, cy, cm, test = _client_data()
    kw = dict(num_clients=SPEC.num_clients, num_select=SPEC.num_select,
              ticks=SPEC.rounds, selector=selector, local=SPEC.local,
              latency=latency, eval_every=SPEC.rounds, seed=0)
    kw.update(acfg_kw)
    srv = AsyncFederatedServer(init_fn, apply_fn, AsyncConfig(**kw),
                               cx, cy, cm, test=test)
    return srv.run()


@functools.lru_cache(maxsize=None)
def _standalone_identity(selector):
    return _standalone(selector)


@pytest.mark.parametrize("selector", ["hics", "cs"])
def test_parity_sweep_driver(selector):
    so, ao = _sweep_parity(selector)
    np.testing.assert_array_equal(so["selected"], ao["selected"])
    assert (so["train_loss"] == ao["train_loss"]).all()      # bit-exact
    assert (so["test_acc"][:, -1] == ao["final_acc"]).all()
    assert ao["fired"].all()              # every tick fires at B = M = K
    assert ao["dropped"].sum() == 0
    np.testing.assert_array_equal(ao["version"][:, -1],
                                  np.full(len(SPEC.seeds), SPEC.rounds))


@pytest.mark.parametrize("selector", ["hics", "cs"])
def test_parity_scanned_server_driver(selector):
    h = _standalone_identity(selector)
    sh = run_host_reference(SPEC, "dir_mild", selector, 0,
                            jit_rounds=True)
    assert h["selected"] == sh["selected"]
    np.testing.assert_array_equal(h["train_loss"], sh["train_loss"])
    np.testing.assert_array_equal(h["test_acc"][-1], sh["test_acc"][-1])
    assert h["aggregations"] == SPEC.rounds and h["dropped_total"] == 0


@pytest.mark.parametrize("selector", ["hics", "cs"])
def test_parity_host_driver(selector):
    h = _standalone_identity(selector)
    sh = run_host_reference(SPEC, "dir_mild", selector, 0,
                            jit_rounds=False)
    assert h["selected"] == sh["selected"]
    np.testing.assert_allclose(h["train_loss"], sh["train_loss"],
                               atol=1e-5)


def test_full_all_selector_rejected():
    # DivFL's ideal mode polls every client every tick — no async
    # semantics; both entry points refuse it up front
    with pytest.raises(ValueError, match="async semantics"):
        build_async_pair(SPEC, "dir_mild", "divfl")
    init_fn, apply_fn, cx, cy, cm, _ = _client_data()
    with pytest.raises(ValueError, match="async semantics"):
        AsyncFederatedServer(
            init_fn, apply_fn,
            AsyncConfig(num_clients=SPEC.num_clients, num_select=2,
                        ticks=4, selector="divfl", local=SPEC.local),
            cx, cy, cm)


# ---------------------------------------------------------------------------
# non-identity traffic: accounting invariants
# ---------------------------------------------------------------------------


def test_straggler_traffic_accounting():
    h = _standalone(
        "hics", capacity=4, threshold=2,
        latency=LatencySpec(kind="stragglers", straggler_frac=0.4,
                            straggler_delay=3, seed=1),
        ticks=10, eval_every=10)
    assert np.isfinite(h["train_loss"]).all()
    assert h["version"] == sorted(h["version"])      # monotone versions
    assert h["aggregations"] >= 1
    # conservation: every accepted arrival is either popped by an
    # aggregation or still buffered at the end
    assert sum(h["accepted"]) == 2 * h["aggregations"] + \
        h["buffer_fill"][-1]
    # arrivals never exceed dispatches (the rest is still in flight)
    assert sum(h["accepted"]) + h["dropped_total"] <= 2 * 10


def test_flash_crowd_overflow_dropped_and_counted():
    h = _standalone(
        "hics", capacity=2, threshold=2,
        latency=LatencySpec(kind="flash_crowd", period=4),
        max_lag=8, ticks=12, eval_every=12)
    assert h["dropped_total"] > 0          # bursts overflow B = K ...
    assert h["aggregations"] >= 1          # ... but training continues
    assert np.isfinite(h["train_loss"]).all()


def test_async_sweep_time_varying_scenario():
    spec = dataclasses.replace(SPEC, scenarios=("diurnal_heavy_tail",))
    res = run_async_sweep(spec, capacity=4, threshold=2)
    cell = res["grid"]["diurnal_heavy_tail/hics"]
    assert np.isfinite(cell["train_loss"]).all()
    sel = np.asarray(cell["selected"])
    assert ((sel >= 0) & (sel < SPEC.num_clients)).all()
    assert all(v >= 1 for v in cell["final_version"])


# ---------------------------------------------------------------------------
# staleness ring: cache refresh == from-scratch under async arrivals
# ---------------------------------------------------------------------------

_N, _K, _C = 10, 3, 5


def _ring_fn(slots=3):
    return make_functional("hics", num_clients=_N, num_select=_K,
                           total_rounds=8, num_classes=_C,
                           stale_slots=slots)


def _upd(fn, state, t, ids, rng):
    ids = np.asarray(ids, np.int32)
    rows = rng.normal(size=(len(ids), _C)).astype(np.float32)
    for i, cid in enumerate(ids):    # duplicate ids carry equal rows so
        first = int(np.where(ids == cid)[0][0])   # the scatter is
        rows[i] = rows[first]                     # deterministic
    return fn.update(state, t, jnp.asarray(ids),
                     Observations(bias_updates=jnp.asarray(rows)))


def test_stale_ring_refresh_matches_scratch():
    fn = _ring_fn()
    rng = np.random.default_rng(0)
    state = fn.init(jax.random.PRNGKey(0))
    # round A: three out-of-order cohorts fill the ring (3·K = 9 ids)
    for t, ids in enumerate([[7, 2, 4], [2, 9, 0], [5, 3, 8]]):
        state = _upd(fn, state, t, ids, rng)
    assert int(state.stale_fill) == 9
    _, state = fn.select(state, 3, jax.random.PRNGKey(1))
    assert int(state.stale_fill) == 0
    # round B: duplicates within + across cohorts, and a K = 0 cohort
    state = _upd(fn, state, 4, [1, 6, 4], rng)
    state = stale_append(state, jnp.zeros((0,), jnp.int32))    # K = 0
    state = _upd(fn, state, 5, [4, 4, 1], rng)
    _, state = fn.select(state, 6, jax.random.PRNGKey(2))
    # from-scratch oracle: refresh every row against an empty cache
    _, dist, stats = hics_selection_step_cached(
        state.delta_b, jnp.zeros_like(state.dist_cache),
        jnp.zeros_like(state.row_stats),
        jnp.arange(_N, dtype=jnp.int32), 0.0025)
    np.testing.assert_allclose(np.asarray(state.dist_cache),
                               np.asarray(dist), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.row_stats),
                               np.asarray(stats), atol=1e-5)


def test_stale_ring_gated_select_is_noop_when_clean():
    fn = _ring_fn()
    rng = np.random.default_rng(1)
    state = _upd(fn, _ring_fn().init(jax.random.PRNGKey(0)), 0,
                 [0, 1, 2], rng)
    _, s1 = fn.select(state, 1, jax.random.PRNGKey(1))
    _, s2 = fn.select(s1, 2, jax.random.PRNGKey(2))   # nothing staled
    np.testing.assert_array_equal(np.asarray(s1.dist_cache),
                                  np.asarray(s2.dist_cache))
    np.testing.assert_array_equal(np.asarray(s1.row_stats),
                                  np.asarray(s2.row_stats))
    assert int(s2.stale_fill) == 0


def test_stale_append_empty_cohort_is_noop():
    state = _ring_fn().init(jax.random.PRNGKey(0))
    assert stale_append(state, jnp.zeros((0,), jnp.int32)) is state


def test_stale_ring_overflow_raises():
    state = _ring_fn().init(jax.random.PRNGKey(0))        # ring = 9
    with pytest.raises(ValueError, match="stale_slots"):
        stale_append(state, jnp.arange(10, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# masked_select with ZERO available clients (satellite: defined picks,
# no NaN weights, on host / scan / sweep drivers)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("selector", ["hics", "random"])
def test_masked_select_zero_available_host(selector):
    fn = make_functional(selector, num_clients=8, num_select=3,
                         total_rounds=4, num_classes=5)
    state = fn.init(jax.random.PRNGKey(0))
    ids, out = masked_select(fn, state, 0, jax.random.PRNGKey(1),
                             jnp.zeros(8, bool), jax.random.PRNGKey(2))
    ids = np.asarray(ids)
    assert ids.shape == (3,) and ((ids >= 0) & (ids < 8)).all()
    w = np.asarray(out.weights)
    assert np.isfinite(w).all()
    np.testing.assert_array_equal(w, np.asarray(state.weights))


def test_masked_select_zero_available_scan_and_sweep(monkeypatch):
    # nobody is ever available: the round proceeds under-provisioned
    # (picks stay defined) instead of deadlocking or going NaN
    scn = dataclasses.replace(get_scenario("flaky_severe"),
                              name="test_all_off", avail_p=1.0)
    monkeypatch.setitem(SCENARIOS, "test_all_off", scn)
    spec = dataclasses.replace(SPEC, scenarios=("test_all_off",),
                               rounds=4)
    pair = build_pair(spec, "test_all_off", "hics")
    v = jax.tree_util.tree_map(np.asarray, pair.vmapped()(
        pair.params0, pair.sstate0, pair.parts, pair.round_keys))
    s = jax.tree_util.tree_map(np.asarray,
                               pair.serial()(*pair.seed_slice(0)))
    for sel in (v["selected"], s["selected"][None]):
        assert ((sel >= 0) & (sel < spec.num_clients)).all()
    assert np.isfinite(v["train_loss"]).all()
    assert np.isfinite(v["test_acc"]).all()
    assert np.isfinite(s["train_loss"]).all()
    np.testing.assert_array_equal(v["selected"][0], s["selected"])
