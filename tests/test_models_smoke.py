"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED variant (2 layers, d_model<=512, <=4 experts) and
runs one forward/train step + one prefill/decode step on CPU, asserting
output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.steps import make_train_step
from repro.models import get_model, input_specs, supports_shape
from repro.optim import adam

ASSIGNED = [a for a in list_archs() if not a.startswith("paper-")]


def _batch_for(cfg, B=2, S=32, train=True):
    rng = np.random.default_rng(0)
    if cfg.kind == "vlm":
        P = cfg.vlm.num_patches
        b = {"patches": jnp.asarray(
                 rng.normal(size=(B, P, cfg.vlm.patch_embed_dim)),
                 jnp.float32),
             "tokens": jnp.asarray(
                 rng.integers(0, cfg.vocab_size, (B, S - P)), jnp.int32)}
        T = S - P
    elif cfg.kind == "audio":
        F = min(cfg.encdec.max_source_frames, S)
        b = {"frames": jnp.asarray(rng.normal(size=(B, F, cfg.d_model)),
                                   jnp.float32),
             "tokens": jnp.asarray(
                 rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
        T = S
    else:
        b = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
        T = S
    if train:
        b["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
        b["loss_mask"] = jnp.ones((B, T), jnp.float32)
    return b


def test_all_assigned_archs_present():
    expected = {"qwen2.5-3b", "seamless-m4t-medium", "rwkv6-3b",
                "pixtral-12b", "mixtral-8x22b", "zamba2-7b",
                "deepseek-coder-33b", "gemma-7b", "granite-moe-1b-a400m",
                "qwen3-8b"}
    assert expected <= set(list_archs())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """Exact published dims (spot checks per the assignment table)."""
    cfg = get_config(arch)
    table = {
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
    }
    L, d, H, KV, ff, V = table[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == KV
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_is_small(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 2
    assert r.d_model <= 512
    if r.moe is not None:
        assert r.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    """One optimizer step on the reduced config: finite loss, params move."""
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = adam(1e-3)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(make_train_step(api, opt, dtype=jnp.float32))
    batch = _batch_for(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["ce_loss"]))
    assert int(new_state["step"]) == 1
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(new_state["params"]),
        jax.tree_util.tree_leaves(params)))
    assert moved > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B=B, S=S, train=False)
    logits, cache = api.prefill(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    lg, cache = api.decode_step(
        params, cache, {"token": jnp.zeros((B, 1), jnp.int32),
                        "pos": jnp.asarray(S, jnp.int32)})
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for sname, shape in SHAPES.items():
        if not supports_shape(cfg, shape):
            assert sname == "long_500k", \
                "only the documented seamless long_500k skip is allowed"
            assert arch == "seamless-m4t-medium"
            continue
        specs = input_specs(cfg, shape)
        assert specs, (arch, sname)
        for k, s in specs.items():
            assert isinstance(s, jax.ShapeDtypeStruct)
            assert all(d > 0 for d in s.shape), (k, s.shape)


def test_decode_is_one_token():
    cfg = get_config("qwen3-8b")
    specs = input_specs(cfg, SHAPES["decode_32k"])
    assert specs["token"].shape == (SHAPES["decode_32k"].global_batch, 1)
