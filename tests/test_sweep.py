"""Sweep engine acceptance: for a fixed key the vmapped multi-seed
sweep reproduces the FederatedServer HOST loop seed-for-seed —
identical participant sets, f32-tolerance accuracies — across ≥ 2
scenarios; plus gating, availability, and trajectory checks."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import SyntheticSpec
from repro.fed import LocalSpec
from repro.scenarios import (SweepSpec, availability_mask, build_pair,
                             get_scenario, run_host_reference, run_sweep,
                             seed_keychain)

SPEC = SweepSpec(
    scenarios=("dir_mild", "mixed_80_20"), selectors=("hics", "random"),
    seeds=(0, 1), num_clients=10, num_select=3, rounds=6,
    samples_train=400, samples_test=120,
    data=SyntheticSpec(dim=16, rank=2, noise=0.5),
    local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1, epochs=1,
                    batch_size=32))


@pytest.fixture(scope="module")
def sweep_results():
    return run_sweep(SPEC)


# ---------------------------------------------------------------------------
# acceptance: vmapped == host loop, per seed, over ≥ 2 scenarios
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", ["dir_mild", "mixed_80_20"])
def test_vmapped_matches_host_loop(sweep_results, scenario):
    cell = sweep_results["grid"][f"{scenario}/hics"]
    for i, seed in enumerate(SPEC.seeds):
        host = run_host_reference(SPEC, scenario, "hics", seed)
        assert host["selected"] == cell["selected"][i].tolist(), \
            f"participant sets diverged (scenario={scenario}, seed={seed})"
        np.testing.assert_allclose(host["test_acc"][-1],
                                   cell["final_acc"][i], atol=1e-5)
        np.testing.assert_allclose(host["train_loss"],
                                   cell["train_loss"][i], atol=1e-5)
        # final-round mean estimated entropy agrees too
        np.testing.assert_allclose(
            np.mean(host["bias_entropy"][-1]),
            cell["mean_entropy"][i][-1], atol=1e-4)


def test_vmapped_matches_host_loop_random_selector(sweep_results):
    cell = sweep_results["grid"]["dir_mild/random"]
    host = run_host_reference(SPEC, "dir_mild", "random", 0)
    assert host["selected"] == cell["selected"][0].tolist()


def test_seeds_actually_differ(sweep_results):
    cell = sweep_results["grid"]["dir_mild/hics"]
    assert cell["selected"].shape == (2, SPEC.rounds, SPEC.num_select)
    assert not np.array_equal(cell["selected"][0], cell["selected"][1])


def test_trajectories_shape_and_finiteness(sweep_results):
    for name, cell in sweep_results["grid"].items():
        assert len(cell["acc_mean"]) == SPEC.rounds
        assert len(cell["entropy_mean"]) == SPEC.rounds
        assert np.isfinite(cell["acc_mean"]).all(), name
        assert np.isfinite(cell["train_loss_mean"]).all(), name
        assert 0.0 <= cell["final_acc_mean"] <= 1.0
    hics = sweep_results["grid"]["dir_mild/hics"]
    assert hics["entropy_mean"][-1] != 0.0     # Ĥ recorded post-sweep


# ---------------------------------------------------------------------------
# serial engine path + availability scenarios
# ---------------------------------------------------------------------------


def test_serial_engine_matches_vmapped_under_dropout():
    spec = dataclasses.replace(SPEC, scenarios=("flaky_severe",),
                               selectors=("hics",))
    pair = build_pair(spec, "flaky_severe", "hics")
    v = pair.vmapped()(pair.params0, pair.sstate0, pair.parts,
                       pair.round_keys)
    for i in range(len(spec.seeds)):
        s = pair.serial()(*pair.seed_slice(i))
        np.testing.assert_array_equal(np.asarray(v["selected"][i]),
                                      np.asarray(s["selected"]))
        np.testing.assert_allclose(np.asarray(v["test_acc"][i]),
                                   np.asarray(s["test_acc"]), atol=1e-5)


def test_dropout_sweep_selects_only_available():
    spec = dataclasses.replace(SPEC, scenarios=("flaky_severe",),
                               selectors=("random",))
    pair = build_pair(spec, "flaky_severe", "random")
    out = pair.vmapped()(pair.params0, pair.sstate0, pair.parts,
                         pair.round_keys)
    scn = get_scenario("flaky_severe")
    for i, seed in enumerate(spec.seeds):
        _, _, round_keys = seed_keychain(seed, spec.rounds)
        for t in range(spec.rounds):
            avail = np.asarray(availability_mask(
                scn, spec.num_clients, t,
                jax.random.fold_in(round_keys[t], 1)))
            picked = np.asarray(out["selected"][i, t])
            if avail.sum() >= spec.num_select:
                assert avail[picked].all(), (seed, t, picked, avail)


def test_host_reference_rejects_time_varying():
    with pytest.raises(ValueError, match="availability"):
        run_host_reference(SPEC, "flaky_severe", "hics", 0)


# ---------------------------------------------------------------------------
# gating + spec plumbing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("selector", ["cs", "divfl"])
def test_full_update_selectors_buildable(selector):
    """CS/DivFL are sweepable: build_pair sizes their feature buffers
    from the model and stacks selector state over seeds.  Sweep-vs-host
    parity for them lives in tests/test_full_update_selectors.py."""
    pair = build_pair(SPEC, "dir_mild", selector)
    assert pair.sstate0.feats.shape[0] == len(SPEC.seeds)
    assert pair.sstate0.feats.shape[1] == SPEC.num_clients
    assert pair.sstate0.feats.shape[2] > 1      # |θ|-sized features


@pytest.mark.parametrize("algo", ["feddyn", "moon"])
def test_stateful_local_algos_match_host(algo):
    """feddyn's per-client h and moon's previous-params memory ride the
    sweep as an (N, ...) extras carry — gathered/scattered by cohort
    ids exactly as the server loop does, so the vmapped engine matches
    the host loop for stateful local algorithms too (the capability gap
    the engine used to reject with a ValueError)."""
    spec = dataclasses.replace(
        SPEC, scenarios=("dir_mild",), rounds=4,
        local=LocalSpec(algo=algo, optimizer="sgd", lr=0.1,
                        epochs=1, batch_size=32, mu=0.1))
    pair = build_pair(spec, "dir_mild", "hics")
    out = pair.vmapped()(pair.params0, pair.sstate0, pair.parts,
                         pair.round_keys)
    host = run_host_reference(spec, "dir_mild", "hics", 0)
    assert host["selected"] == np.asarray(out["selected"][0]).tolist()
    np.testing.assert_allclose(host["train_loss"],
                               np.asarray(out["train_loss"][0]),
                               atol=1e-5)
    np.testing.assert_allclose(host["test_acc"][-1],
                               np.asarray(out["test_acc"][0, -1]),
                               atol=1e-5)


def test_unknown_names_rejected():
    with pytest.raises(KeyError, match="unknown selector"):
        build_pair(SPEC, "dir_mild", "nope")
    with pytest.raises(KeyError, match="unknown scenario"):
        build_pair(SPEC, "nope", "hics")


def test_capacity_default_and_override():
    assert SPEC.capacity() == 4 * 400 // 10
    assert dataclasses.replace(SPEC, cap=33).capacity() == 33
    assert dataclasses.replace(
        SPEC, num_clients=2).capacity() == 400      # clipped to S


def test_loss_all_selector_runs_in_sweep():
    """pow-d needs the per-round all-client loss poll on-device."""
    spec = dataclasses.replace(SPEC, scenarios=("dir_mild",),
                               selectors=("pow-d",), rounds=4)
    res = run_sweep(spec)
    cell = res["grid"]["dir_mild/pow-d"]
    assert np.isfinite(cell["acc_mean"]).all()
    host = run_host_reference(spec, "dir_mild", "pow-d", 0)
    assert host["selected"] == cell["selected"][0].tolist()
