"""Scanned round loop (`jit_rounds=True`): host/scan participant-set
parity, single compilation, and selector gating."""
import numpy as np
import pytest

from repro.fed import ExperimentSpec, LocalSpec, build


def _spec(selector, jit_rounds, rounds=20, **kw):
    return ExperimentSpec(
        arch="paper-mlp", num_clients=12, num_select=3, rounds=rounds,
        alphas=(0.05, 5.0), selector=selector,
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                        epochs=2, batch_size=32),
        samples_train=600, samples_test=200, eval_every=5, seed=0,
        jit_rounds=jit_rounds, **kw)


def test_hics_scan_matches_host_loop_20_rounds():
    """Acceptance: with jit_rounds=True the scanned round_step produces
    participant sets identical to the host loop for 20 rounds, same
    seed — selection state never leaves the device between select and
    update."""
    host, _ = build(_spec("hics", False))
    scan, _ = build(_spec("hics", True))
    h_host = host.run()
    h_scan = scan.run()
    assert h_host["selected"] == h_scan["selected"]
    assert len(h_scan["selected"]) == 20
    # losses agree to float-fusion tolerance; entropies to f32 eps
    np.testing.assert_allclose(h_host["train_loss"], h_scan["train_loss"],
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_host["bias_entropy"][-1]),
                               np.asarray(h_scan["bias_entropy"][-1]),
                               atol=1e-5)


def test_round_step_compiles_once():
    """The scanned round_step traces exactly once across 20 rounds
    (4 × eval_every-sized segments hit the same jitted scan)."""
    server, _ = build(_spec("hics", True))
    traces = []
    step = server._make_round_step()

    def counting(carry, xs):
        traces.append(1)
        return step(carry, xs)

    server._round_step = counting
    hist = server.run()
    assert len(hist["round"]) == 20
    assert len(traces) == 1, f"round_step traced {len(traces)} times"


@pytest.mark.parametrize("selector", ["random", "pow-d", "fedcor"])
def test_scan_parity_other_selectors(selector):
    host, _ = build(_spec(selector, False, rounds=12))
    scan, _ = build(_spec(selector, True, rounds=12))
    assert host.run()["selected"] == scan.run()["selected"]


@pytest.mark.parametrize("selector", ["cs", "divfl"])
def test_full_update_selectors_scan(selector):
    """CS/DivFL ride the scanned loop: their full-update observations
    (participant deltas / the all-clients gradient poll) are computed
    inside the jitted round step.  The 30-round host/scan/sweep parity
    battery lives in tests/test_full_update_selectors.py — this is the
    gating smoke check."""
    server, _ = build(_spec(selector, True, rounds=4))
    hist = server.run()
    assert len(hist["round"]) == 4
    assert all(len(ids) == 3 for ids in hist["selected"])


def test_scan_state_writeback():
    """After a scanned run the shim's state reflects the final round —
    a follow-up host-loop round continues seamlessly."""
    server, _ = build(_spec("hics", True, rounds=10))
    server.run()
    assert int(server.selector.state.hist_count) == 10
    assert np.asarray(server.selector.state.seen).all()   # sweep done
    ids = server.selector.select(10)
    assert len(set(ids)) == 3
