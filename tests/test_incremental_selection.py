"""Incremental selection parity battery.

The cached K-row path (``hics_selection_step_cached`` + the
``dist_cache``/``row_stats``/``stale_ids`` state fields) must be
indistinguishable from from-scratch recomputation everywhere it can be
observed: the refreshed matrix itself (property test over random
shapes/index sets, both backends, bf16 included), the cluster labels it
feeds, the participant sets of whole federated runs (host loop, scanned
loop, vmapped sweep — ≥50 rounds), and under availability masking
(masked-out clients never poison cached rows; no NaNs leak into
entropies or sampling weights).
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.core import Observations, agglomerate_device, make_functional
from repro.core.selectors.functional import SelectorState
from repro.data import SyntheticSpec
from repro.fed import ExperimentSpec, LocalSpec, build
from repro.kernels import (gram_row_update, hics_selection_step,
                           hics_selection_step_cached)
from repro.scenarios import (SweepSpec, availability_mask, build_pair,
                             get_scenario, masked_select,
                             run_host_reference, seed_keychain)

T_SOFT, LAM = 0.0025, 10.0


def _fresh_cache(x, normalize=False, use_pallas=False):
    """Build a valid cache by refreshing ALL rows from the zero cache."""
    n = x.shape[0]
    _, dist, stats = hics_selection_step_cached(
        x, jnp.zeros((n, n)), jnp.zeros((n, 2)),
        jnp.arange(n, dtype=jnp.int32), T_SOFT, lam=LAM,
        normalize=normalize, use_pallas=use_pallas)
    return dist, stats


# ---------------------------------------------------------------------------
# property test: incremental == full recompute, labels identical
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=25)
@given(st.integers(4, 32), st.integers(2, 40), st.integers(0, 40),
       st.booleans(), st.integers(0, 2**31 - 1))
def test_incremental_matches_full_recompute(n, c, k, normalize, seed):
    """Random (N, C, K) and random replacement index sets — duplicates
    included, K clipped into [0, N] — leave the cached matrix within fp
    tolerance of from-scratch recompute, with identical cluster labels
    and exact symmetry."""
    k = min(k, n)
    r = np.random.default_rng(seed)
    x0 = jnp.asarray(r.normal(size=(n, c)) * 0.02, jnp.float32)
    dist, stats = _fresh_cache(x0, normalize=normalize)
    # two successive replacement rounds (drift must not accumulate)
    x = x0
    for _ in range(2):
        ids = jnp.asarray(r.integers(0, n, size=k), jnp.int32)
        rows = jnp.asarray(r.normal(size=(k, c)) * 0.02, jnp.float32)
        x = x.at[ids].set(rows)
        ent, dist, stats = hics_selection_step_cached(
            x, dist, stats, ids, T_SOFT, lam=LAM, normalize=normalize,
            use_pallas=False)
    ent_f, dist_f = hics_selection_step(x, T_SOFT, lam=LAM,
                                        normalize=normalize,
                                        use_pallas=False)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_f),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_f),
                               atol=1e-6)
    d = np.asarray(dist)
    np.testing.assert_array_equal(d, d.T)          # exactly symmetric
    np.testing.assert_array_equal(np.diag(d), 0.0)
    m = max(1, min(4, n - 1))
    lab_c = np.asarray(agglomerate_device(dist, m, precomputed=True))
    lab_f = np.asarray(agglomerate_device(dist_f, m))
    np.testing.assert_array_equal(lab_c, lab_f)


def test_k_equals_zero_returns_cache_unchanged(rng):
    x = jnp.asarray(rng.normal(size=(10, 6)) * 0.02, jnp.float32)
    dist, stats = _fresh_cache(x)
    ent, d2, s2 = hics_selection_step_cached(
        x, dist, stats, jnp.zeros(0, jnp.int32), T_SOFT, lam=LAM,
        use_pallas=False)
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(stats))
    np.testing.assert_array_equal(np.asarray(ent),
                                  np.asarray(stats[:, 1]))


def test_k_equals_n_equals_full_step(rng):
    """Replacing every row IS the from-scratch step (fp tolerance)."""
    x = jnp.asarray(rng.normal(size=(17, 9)) * 0.02, jnp.float32)
    ent, dist, _ = hics_selection_step_cached(
        x, jnp.zeros((17, 17)), jnp.zeros((17, 2)),
        jnp.arange(17, dtype=jnp.int32), T_SOFT, lam=LAM,
        use_pallas=False)
    ent_f, dist_f = hics_selection_step(x, T_SOFT, lam=LAM,
                                        use_pallas=False)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(dist_f),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_f),
                               atol=1e-6)


def test_duplicate_ids_are_harmless(rng):
    x0 = jnp.asarray(rng.normal(size=(12, 5)) * 0.02, jnp.float32)
    dist, stats = _fresh_cache(x0)
    rows = jnp.asarray(rng.normal(size=(4, 5)) * 0.02, jnp.float32)
    dup = jnp.asarray([3, 7, 3, 3], jnp.int32)
    x1 = x0.at[dup].set(rows)      # scatter resolves the duplicates
    _, d_dup, _ = hics_selection_step_cached(x1, dist, stats, dup,
                                             T_SOFT, lam=LAM,
                                             use_pallas=False)
    _, d_full = hics_selection_step(x1, T_SOFT, lam=LAM,
                                    use_pallas=False)
    np.testing.assert_allclose(np.asarray(d_dup), np.asarray(d_full),
                               atol=1e-5)


@pytest.mark.parametrize("gram_in_bf16", [False, True])
def test_pallas_cached_matches_pallas_full(rng, gram_in_bf16):
    """Kernel path (interpret mode), f32 and bf16-Gram variants: the
    cached strip kernel agrees with the full fused kernel."""
    n, c, k = 20, 260, 6
    x0 = jnp.asarray(rng.normal(size=(n, c)) * 0.02, jnp.float32)
    dist, stats = _fresh_cache(x0, use_pallas=True)
    ids = jnp.asarray(rng.integers(0, n, size=k), jnp.int32)
    x1 = x0.at[ids].set(jnp.asarray(rng.normal(size=(k, c)) * 0.02,
                                    jnp.float32))
    ent, d_c, s_c = hics_selection_step_cached(
        x1, dist, stats, ids, T_SOFT, lam=LAM,
        gram_in_bf16=gram_in_bf16, use_pallas=True)
    ent_f, d_f = hics_selection_step(x1, T_SOFT, lam=LAM,
                                     gram_in_bf16=gram_in_bf16,
                                     use_pallas=True)
    tol = 1e-4 if not gram_in_bf16 else 3e-2
    np.testing.assert_allclose(np.asarray(d_c), np.asarray(d_f),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_f),
                               atol=1e-4)
    m = 4
    np.testing.assert_array_equal(
        np.asarray(agglomerate_device(d_c, m, precomputed=True)),
        np.asarray(agglomerate_device(d_f, m)))


def test_gram_row_update_strip_matches_cache_rows(rng):
    """The raw strip op equals the rows the cached step writes."""
    n, c, k = 15, 33, 5
    x = jnp.asarray(rng.normal(size=(n, c)) * 0.02, jnp.float32)
    dist, stats = _fresh_cache(x)
    ids = jnp.asarray(rng.choice(n, size=k, replace=False), jnp.int32)
    strip = gram_row_update(x, stats, ids, lam=LAM, use_pallas=False)
    assert strip.shape == (k, n)
    np.testing.assert_allclose(np.asarray(strip),
                               np.asarray(dist[ids]), atol=1e-6)
    strip_p = gram_row_update(x, stats, ids, lam=LAM, use_pallas=True)
    np.testing.assert_allclose(np.asarray(strip_p), np.asarray(strip),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# selector-level parity: incremental triple == from-scratch triple
# ---------------------------------------------------------------------------


def _drive(fn, t_max, n, c, seed):
    r = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    state = fn.init(k0)
    picks = []
    for t in range(t_max):
        key, kt = jax.random.split(key)
        ids, state = fn.select(state, t, kt)
        picks.append(np.asarray(ids).tolist())
        obs = Observations(bias_updates=jnp.asarray(
            r.normal(size=(ids.shape[0], c)) * 0.02, jnp.float32))
        state = fn.update(state, t, ids, obs)
    return picks, state


@settings(deadline=None, max_examples=8)
@given(st.integers(6, 20), st.integers(1, 5), st.integers(2, 12),
       st.integers(0, 2**31 - 1))
def test_functional_triple_parity_shape_sweep(n, k, c, seed):
    """Hypothesis sweep: the incremental and from-scratch selectors
    pick identical participant sets from the same key/observation
    chain (the obs chain is identical because the picks are)."""
    k = min(k, n)
    kw = dict(num_clients=n, num_select=k, total_rounds=12,
              num_classes=c)
    fn_inc = make_functional("hics", incremental=True, **kw)
    fn_full = make_functional("hics", incremental=False, **kw)
    p_inc, s_inc = _drive(fn_inc, 12, n, c, seed % 9973)
    p_full, _ = _drive(fn_full, 12, n, c, seed % 9973)
    assert p_inc == p_full
    # the incremental state really carries the cache
    assert s_inc.dist_cache.shape == (n, n)
    assert s_inc.row_stats.shape == (n, 2)
    assert s_inc.stale_ids.shape == (k,)


def test_from_scratch_state_skips_cache_memory():
    fn = make_functional("hics", num_clients=8, num_select=2,
                         total_rounds=5, num_classes=4,
                         incremental=False)
    state = fn.init(jax.random.PRNGKey(0))
    assert state.dist_cache.shape == (8, 0)
    assert state.row_stats.shape == (8, 0)
    assert state.stale_ids.shape == (0,)


# ---------------------------------------------------------------------------
# long-horizon drift: 50 rounds through host / scanned / sweep loops
# ---------------------------------------------------------------------------

ROUNDS = 50


def _spec(incremental, jit_rounds):
    return ExperimentSpec(
        arch="paper-mlp", num_clients=12, num_select=3, rounds=ROUNDS,
        alphas=(0.05, 5.0), selector="hics",
        selector_kw={"incremental": incremental},
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                        epochs=1, batch_size=32),
        samples_train=400, samples_test=120, eval_every=10 ** 6,
        seed=0, jit_rounds=jit_rounds)


@pytest.fixture(scope="module")
def host_runs():
    inc, _ = build(_spec(True, False))
    full, _ = build(_spec(False, False))
    return inc.run(), full.run()


def test_host_loop_50_round_drift(host_runs):
    """Acceptance: 50 host-loop rounds of incremental HiCS produce
    participant sets identical to the from-scratch selector."""
    h_inc, h_full = host_runs
    assert len(h_inc["selected"]) == ROUNDS
    assert h_inc["selected"] == h_full["selected"]
    np.testing.assert_allclose(h_inc["train_loss"], h_full["train_loss"],
                               atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(h_inc["bias_entropy"][-1]),
        np.asarray(h_full["bias_entropy"][-1]), atol=1e-5)


def test_scanned_loop_50_round_drift_single_compile(host_runs):
    """The scanned (jit_rounds=True) incremental run matches the host
    loops round-for-round AND its cached round_step traces exactly
    once across all 50 rounds."""
    h_inc, _ = host_runs
    server, _ = build(_spec(True, True))
    traces = []
    step = server._make_round_step()

    def counting(carry, xs):
        traces.append(1)
        return step(carry, xs)

    server._round_step = counting
    h_scan = server.run()
    assert h_scan["selected"] == h_inc["selected"]
    assert len(traces) == 1, f"round_step traced {len(traces)} times"
    # scan leaves a live, fully-refreshed cache behind
    state = server.selector.state
    assert np.isfinite(np.asarray(state.dist_cache)).all()
    assert np.isfinite(np.asarray(state.row_stats)).all()


SWEEP = SweepSpec(
    scenarios=("dir_mild",), selectors=("hics",), seeds=(0, 1),
    num_clients=10, num_select=3, rounds=ROUNDS,
    samples_train=400, samples_test=120,
    data=SyntheticSpec(dim=16, rank=2, noise=0.5),
    local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1, epochs=1,
                    batch_size=32))


def test_vmapped_sweep_50_round_drift():
    """The cache rides the vmapped seed axis: per-seed participant
    sets of the incremental sweep equal the from-scratch sweep AND the
    host-loop oracle over 50 rounds."""
    spec_inc = dataclasses.replace(
        SWEEP, selector_kw={"incremental": True})
    spec_full = dataclasses.replace(
        SWEEP, selector_kw={"incremental": False})
    pair_inc = build_pair(spec_inc, "dir_mild", "hics")
    pair_full = build_pair(spec_full, "dir_mild", "hics")
    assert pair_inc.sstate0.dist_cache.shape == (2, 10, 10)  # seed axis
    out_inc = pair_inc.vmapped()(pair_inc.params0, pair_inc.sstate0,
                                 pair_inc.parts, pair_inc.round_keys)
    out_full = pair_full.vmapped()(pair_full.params0, pair_full.sstate0,
                                   pair_full.parts,
                                   pair_full.round_keys)
    np.testing.assert_array_equal(np.asarray(out_inc["selected"]),
                                  np.asarray(out_full["selected"]))
    for i, seed in enumerate(SWEEP.seeds):
        host = run_host_reference(spec_inc, "dir_mild", "hics", seed)
        assert host["selected"] == \
            np.asarray(out_inc["selected"][i]).tolist()


# ---------------------------------------------------------------------------
# availability / masking: the cache never sees masked-out clients
# ---------------------------------------------------------------------------


def _masked_drive(scenario_name, incremental, t_max=14, n=10, k=3, c=6,
                  seed=0):
    scn = get_scenario(scenario_name)
    fn = make_functional("hics", num_clients=n, num_select=k,
                         total_rounds=t_max, num_classes=c,
                         incremental=incremental)
    _, k_sel, round_keys = seed_keychain(seed, t_max)
    state = fn.init(k_sel)
    r = np.random.default_rng(seed)
    picks, states = [], []
    for t in range(t_max):
        kr = round_keys[t]
        k_s, _ = jax.random.split(kr)
        avail = availability_mask(scn, n, t, jax.random.fold_in(kr, 1))
        prev = state
        ids, state = masked_select(fn, state, t, k_s, avail,
                                   jax.random.fold_in(kr, 2))
        picks.append(np.asarray(ids).tolist())
        states.append((np.asarray(avail), np.asarray(prev.delta_b),
                       np.asarray(prev.row_stats), np.asarray(ids),
                       np.asarray(prev.stale_ids), state))
        obs = Observations(bias_updates=jnp.asarray(
            r.normal(size=(k, c)) * 0.02, jnp.float32))
        state = fn.update(state, t, ids, obs)
    return picks, states, state


@pytest.mark.parametrize("scenario", ["flaky_severe", "diurnal_mixed"])
def test_masked_cache_no_nans_and_no_poisoning(scenario):
    """Dropout/diurnal masks interacting with the cache leak no NaNs
    into entropies, distances or sampling weights, and only the rows
    staled by the previous update are ever rewritten — masked-out
    bystanders keep their cached rows bit-for-bit."""
    picks, states, final = _masked_drive(scenario, incremental=True)
    for avail, db_prev, stats_prev, ids, stale_prev, out in states:
        out_stats = np.asarray(out.row_stats)
        assert np.isfinite(out_stats).all()
        assert np.isfinite(np.asarray(out.dist_cache)).all()
        # masking is per-round: original weights restored, finite
        w = np.asarray(out.weights)
        assert np.isfinite(w).all() and w.sum() > 0
        # rows whose stats changed across this select ⊆ staled rows
        changed = np.flatnonzero(
            np.any(out_stats != stats_prev, axis=-1))
        assert set(changed) <= set(stale_prev.tolist())
        if avail.sum() >= len(ids):
            assert avail[ids].all()
    ent = np.asarray(final.row_stats[:, 1])
    assert np.isfinite(ent).all()


@pytest.mark.parametrize("scenario", ["flaky_severe", "diurnal_mixed"])
def test_masked_parity_incremental_vs_full(scenario):
    """Same key/obs chain under masking: incremental == from-scratch."""
    p_inc, _, _ = _masked_drive(scenario, incremental=True)
    p_full, _, _ = _masked_drive(scenario, incremental=False)
    assert p_inc == p_full


def test_masked_sweep_runs_finite_with_incremental_cache():
    """The whole dropout scenario through the vmapped sweep engine with
    the cache on the seed axis stays finite end-to-end."""
    spec = dataclasses.replace(
        SWEEP, scenarios=("flaky_severe",), rounds=8,
        selector_kw={"incremental": True})
    pair = build_pair(spec, "flaky_severe", "hics")
    out = pair.vmapped()(pair.params0, pair.sstate0, pair.parts,
                         pair.round_keys)
    assert np.isfinite(np.asarray(out["test_acc"])).all()
    assert np.isfinite(np.asarray(out["mean_entropy"])).all()


# ---------------------------------------------------------------------------
# OO shim / entropy-history integration
# ---------------------------------------------------------------------------


def test_shim_rejects_double_update_without_select(rng):
    """The (K,) staleness buffer only covers one update; a second
    update before the next select would silently leave the first
    cohort's cached rows stale — the shim fails fast instead.  The
    from-scratch selector has no such restriction."""
    from repro.core import make_selector
    db = rng.normal(0, 0.02, (8, 4))
    sel = make_selector("hics", num_clients=8, num_select=2,
                        total_rounds=6, seed=0, num_classes=4)
    ids = sel.select(0)
    sel.update(0, ids, bias_updates=db[ids])
    with pytest.raises(RuntimeError, match="intervening select"):
        sel.update(0, ids, bias_updates=db[ids])
    sel.select(1)                       # refresh clears the hazard
    sel.update(1, ids, bias_updates=db[ids])
    full = make_selector("hics", num_clients=8, num_select=2,
                         total_rounds=6, seed=0, num_classes=4,
                         incremental=False)
    ids = full.select(0)
    full.update(0, ids, bias_updates=db[ids])
    full.update(0, ids, bias_updates=db[ids])   # no cache, no hazard


def test_shim_incremental_parity_with_full(rng):
    """Through the legacy OO shim (standalone key discipline, width
    growth via _ensure_dims): incremental == from-scratch."""
    from repro.core import make_selector
    n, k, c, t_max = 16, 4, 8, 10
    db = rng.normal(0, 0.02, (n, c))
    picks = {}
    for inc in (True, False):
        sel = make_selector("hics", num_clients=n, num_select=k,
                            total_rounds=t_max, seed=3,
                            incremental=inc)
        got = []
        for t in range(t_max):
            ids = sel.select(t)
            got.append(list(ids))
            sel.update(t, ids, bias_updates=db[ids])
        picks[inc] = got
        ent = sel.estimated_entropies()
        assert ent is not None and np.isfinite(ent).all()
    assert picks[True] == picks[False]
