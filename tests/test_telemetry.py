"""Telemetry subsystem acceptance.

The contract (src/repro/telemetry/metrics.py): enabling any metric
group combination changes ONLY what is recorded, never what is
computed — participant sets, losses, and final parameters stay
bit-identical to the telemetry-off run on every driver (host loop,
scanned loop, vmapped sweep, async tick scan); the scanned drivers
still compile exactly once; and every driver emits the same flat
``{"group/field": array}`` schema, with zero-width arrays for
disabled/unavailable fields.  Plus the JSONL export round-trip.
"""
import dataclasses
import json

import numpy as np
import pytest

import jax

from repro.data import SyntheticSpec
from repro.fed import (AsyncConfig, AsyncFederatedServer, ExperimentSpec,
                       LocalSpec, build)
from repro.scenarios import SweepSpec, make_dataset, materialize, run_sweep
from repro.scenarios.sweep import _make_model
from repro.configs import get_config
from repro.telemetry import (GROUPS, MetricsSpec, TelemetryCtx,
                             make_metrics, read_jsonl, summarize,
                             telemetry_from_records, write_run)

SYNC_GROUPS = ("selection", "training", "fairness")


def _spec(telemetry=(), jit_rounds=True, rounds=8):
    return ExperimentSpec(
        arch="paper-mlp", num_clients=12, num_select=3, rounds=rounds,
        alphas=(0.05, 5.0), selector="hics",
        local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                        epochs=1, batch_size=32),
        samples_train=400, samples_test=120, eval_every=4, seed=0,
        jit_rounds=jit_rounds, telemetry=telemetry)


def _run(telemetry=(), jit_rounds=True, rounds=8):
    server, _ = build(_spec(telemetry, jit_rounds, rounds))
    hist = server.run()
    return server, hist


SWEEP_SPEC = SweepSpec(
    scenarios=("dir_mild",), selectors=("hics",), seeds=(0, 1),
    num_clients=10, num_select=3, rounds=6,
    samples_train=400, samples_test=120,
    data=SyntheticSpec(dim=16, rank=2, noise=0.5),
    local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1, epochs=1,
                    batch_size=32))


def _make_async_server(telemetry):
    spec = SWEEP_SPEC
    scn = spec.scenario("dir_mild")
    cfg = get_config(spec.arch)
    train, test, _ = make_dataset(scn, spec.samples_train,
                                  spec.samples_test, cfg.vocab_size,
                                  spec.data_seed)
    part = materialize(scn, 0, train, cfg.vocab_size, spec.num_clients,
                       spec.capacity())
    init_fn, apply_fn, _ = _make_model(spec, cfg, scn.data.dim)
    idx = np.asarray(part.idx)
    acfg = AsyncConfig(num_clients=spec.num_clients, num_select=3,
                       ticks=spec.rounds, selector="hics",
                       local=spec.local, eval_every=spec.rounds,
                       seed=0, telemetry=telemetry)
    return AsyncFederatedServer(
        init_fn, apply_fn, acfg, np.asarray(train["x"])[idx],
        np.asarray(train["y"])[idx], np.asarray(part.mask),
        test={k: np.asarray(v) for k, v in test.items()})


def _async_servers(telemetry):
    out = []
    for tel in ((), telemetry):
        srv = _make_async_server(tel)
        out.append((srv, srv.run()))
    return out


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# spec / schema basics
# ---------------------------------------------------------------------------


def test_unknown_group_rejected():
    with pytest.raises(ValueError, match="unknown metric group"):
        MetricsSpec(("selektion",))


def test_all_spec_covers_registry():
    assert MetricsSpec.all().groups == GROUPS


def test_disabled_groups_zero_width_stable_structure():
    """Off and on runs of the raw (init, step) pair produce the same
    pytree structure; disabled fields are (0,)-shaped."""
    off = make_metrics(MetricsSpec())
    on = make_metrics(MetricsSpec(("training", "fairness")),
                      num_clients=8, num_select=2)
    ctx = TelemetryCtx(t=0, ids=np.array([1, 3]), train_loss=0.5)
    _, tel_off = off.step(off.init(), ctx)
    _, tel_on = on.step(on.init(), ctx)
    assert set(tel_off) == set(tel_on)          # identical field set
    assert all(v.shape == (0,) for v in tel_off.values())
    assert tel_on["training/loss"].shape == ()
    assert tel_on["fairness/sel_counts"].shape == (8,)
    # training fields the ctx didn't supply stay zero-width even when
    # the group is enabled
    assert tel_on["training/update_norm"].shape == (0,)


def test_fairness_counts_accumulate():
    m = make_metrics(MetricsSpec(("fairness",)), num_clients=6,
                     num_select=2)
    carry = m.init()
    for ids in ([0, 1], [1, 2], [1, 5]):
        carry, tel = m.step(carry, TelemetryCtx(ids=np.asarray(ids)))
    np.testing.assert_array_equal(np.asarray(tel["fairness/sel_counts"]),
                                  [1, 3, 1, 0, 0, 1])
    assert float(tel["fairness/participation"]) == pytest.approx(4 / 6)
    assert 0.0 < float(tel["fairness/eff_participation"]) <= 1.0


# ---------------------------------------------------------------------------
# invariance: telemetry never perturbs the run (the core guarantee)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("jit_rounds", [False, True],
                         ids=["host", "scanned"])
def test_server_invariant_under_telemetry(jit_rounds):
    s_off, h_off = _run((), jit_rounds)
    s_on, h_on = _run(SYNC_GROUPS, jit_rounds)
    assert h_off["selected"] == h_on["selected"]
    np.testing.assert_array_equal(h_off["train_loss"], h_on["train_loss"])
    _assert_trees_equal(s_off.params, s_on.params)
    # and the recording itself materialized, (T,)-shaped
    tel = s_on.telemetry
    assert tel["training/loss"].shape == (8,)
    assert tel["selection/ent_mean"].shape == (8,)
    assert tel["fairness/sel_counts"].shape == (8, 12)
    # final-round histogram == the actual selection counts
    counts = np.bincount(np.concatenate(h_on["selected"]), minlength=12)
    np.testing.assert_array_equal(tel["fairness/sel_counts"][-1], counts)


def test_partial_group_combo_invariant():
    _, h_off = _run((), True)
    s_on, h_on = _run(("fairness",), True)
    assert h_off["selected"] == h_on["selected"]
    # disabled groups stay zero-width in the stacked output
    assert s_on.telemetry["training/loss"].shape == (8, 0)
    assert s_on.telemetry["fairness/participation"].shape == (8,)


def test_sweep_invariant_under_telemetry():
    off = run_sweep(SWEEP_SPEC)
    on = run_sweep(dataclasses.replace(SWEEP_SPEC,
                                       telemetry=SYNC_GROUPS))
    c_off = off["grid"]["dir_mild/hics"]
    c_on = on["grid"]["dir_mild/hics"]
    np.testing.assert_array_equal(c_off["selected"], c_on["selected"])
    np.testing.assert_array_equal(c_off["train_loss"], c_on["train_loss"])
    tel = c_on["telemetry"]                 # {field: (seeds, T, ...)}
    assert tel["training/loss"].shape == (2, 6)
    assert tel["fairness/sel_counts"].shape == (2, 6, 10)
    assert tel["selection/ent_rank_corr"].shape == (2, 6)
    assert np.all(np.abs(tel["selection/ent_rank_corr"]) <= 1.0 + 1e-6)
    assert np.all(tel["selection/ent_mae"] >= 0.0)


def test_async_invariant_under_telemetry():
    (s_off, h_off), (s_on, h_on) = _async_servers(GROUPS)
    assert h_off["selected"] == h_on["selected"]
    np.testing.assert_array_equal(h_off["train_loss"], h_on["train_loss"])
    _assert_trees_equal(s_off.params, s_on.params)
    tel = s_on.telemetry
    T = SWEEP_SPEC.rounds
    assert tel["async/fill"].shape == (T,)
    assert tel["async/version"].shape == (T,)
    assert tel["training/loss"].shape == (T,)
    # identity latency at B = M = K: every tick fires, lag stays 0
    assert np.all(tel["async/fired"] == 1.0)
    assert np.all(tel["async/version_lag"] == 0.0)
    # staleness ages: (T, M) with −1 padding only when a tick idles
    assert tel["async/agg_ages"].ndim == 2
    assert np.all(tel["async/agg_ages"] >= -1.0)


# ---------------------------------------------------------------------------
# single compilation with telemetry enabled
# ---------------------------------------------------------------------------


def test_scanned_round_step_compiles_once_with_telemetry():
    server, _ = build(_spec(SYNC_GROUPS, True))
    traces = []
    step = server._make_round_step()

    def counting(carry, xs):
        traces.append(1)
        return step(carry, xs)

    server._round_step = counting
    hist = server.run()
    assert len(hist["round"]) == 8
    assert len(traces) == 1, f"round_step traced {len(traces)} times"


def test_vmapped_sweep_compiles_once_with_telemetry():
    """The whole per-seed program (telemetry included) traces once
    under the seed vmap."""
    from repro.scenarios import build_pair
    pair = build_pair(dataclasses.replace(SWEEP_SPEC,
                                          telemetry=SYNC_GROUPS),
                      "dir_mild", "hics")
    traces = []

    def counting(*args):
        traces.append(1)
        return pair.run_seed(*args)

    out = jax.jit(jax.vmap(counting))(pair.params0, pair.sstate0,
                                      pair.parts, pair.round_keys)
    assert out["telemetry"]["training/loss"].shape == (2, 6)
    assert len(traces) == 1, f"run_seed traced {len(traces)} times"


def test_async_tick_step_compiles_once_with_telemetry():
    srv = _make_async_server(GROUPS)     # fresh — nothing compiled yet
    traces = []
    step = srv._tick_step

    def counting(carry, xs):
        traces.append(1)
        return step(carry, xs)

    srv._tick_step = counting
    hist = srv.run()
    assert len(hist["round"]) == SWEEP_SPEC.rounds
    assert len(traces) == 1, f"tick_step traced {len(traces)} times"


# ---------------------------------------------------------------------------
# shared schema across drivers
# ---------------------------------------------------------------------------


def test_drivers_emit_identical_field_set():
    s_scan, _ = _run(SYNC_GROUPS, True)
    s_host, _ = _run(SYNC_GROUPS, False)
    on = run_sweep(dataclasses.replace(SWEEP_SPEC,
                                       telemetry=SYNC_GROUPS))
    sweep_tel = on["grid"]["dir_mild/hics"]["telemetry"]
    (_, _), (s_async, _) = _async_servers(GROUPS)
    fields = set(s_scan.telemetry)
    assert set(s_host.telemetry) == fields
    assert set(sweep_tel) == fields
    assert set(s_async.telemetry) == fields


# ---------------------------------------------------------------------------
# JSONL export round-trip
# ---------------------------------------------------------------------------


def test_write_run_roundtrip(tmp_path):
    s_on, _ = _run(SYNC_GROUPS, True)
    path = tmp_path / "run.jsonl"
    summary = write_run(path, s_on.telemetry, meta={"driver": "test"})
    recs = read_jsonl(path)
    header, rounds = recs[0], recs[1:]
    assert header["kind"] == "header"
    assert header["meta"]["driver"] == "test"
    assert {"backend", "device_kind", "cpu_count"} <= set(header["env"])
    assert len(rounds) == 8
    back = telemetry_from_records(rounds)
    live = {k: v for k, v in s_on.telemetry.items() if 0 not in v.shape}
    assert set(back) == set(live)
    for k in live:
        np.testing.assert_allclose(back[k], live[k], rtol=1e-6)
    # summary covers every live scalar field
    assert summary["training/loss"]["last"] == pytest.approx(
        float(s_on.telemetry["training/loss"][-1]))


def test_summarize_matches_numpy():
    tel = {"training/loss": np.asarray([3.0, 2.0, 1.0], np.float32)}
    s = summarize(tel)["training/loss"]
    assert s["last"] == 1.0 and s["min"] == 1.0 and s["max"] == 3.0
    assert s["mean"] == pytest.approx(2.0)


def test_jsonl_is_plain_json_lines(tmp_path):
    s_on, _ = _run(("training",), True)
    path = tmp_path / "run.jsonl"
    write_run(path, s_on.telemetry, meta={})
    for line in path.read_text().splitlines():
        json.loads(line)                      # every line parses alone
