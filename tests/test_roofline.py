"""Roofline machinery: HLO cost parser (trip-count weighting, collective
accounting) and the three-term roofline."""
import numpy as np
import pytest

from repro.roofline import HW_V5E, model_flops, roofline_terms
from repro.roofline.analysis import parse_collectives
from repro.roofline.hlo_cost import analyze
from repro.configs import SHAPES, get_config

_HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (pc: (s32[], f32[8,8])) -> pred[] {
  %pc = (s32[], f32[8,8]) parameter(0)
  %ic = s32[] get-tuple-element(%pc), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%ic, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,8]) -> (s32[], f32[8,8]) {
  %arg = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %arg)
  %ag = f32[16,8]{1,0} all-gather(%arg), dimensions={0}
  ROOT %w0 = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_hlo_trip_count_weighting():
    res = analyze(_HLO)
    assert res["parse_ok"]
    # dot: 2 * 64 * 8 = 1024 flops, x10 trips
    assert res["flops"] == pytest.approx(10 * 2 * 64 * 8)
    # collectives: all-reduce 256B x10 trips (x2 wire) + all-gather 512B x1
    assert res["collective_bytes"]["all-reduce"] == pytest.approx(2560)
    assert res["collective_bytes"]["all-gather"] == pytest.approx(512)
    assert res["collective_total_weighted"] == pytest.approx(
        2 * 2560 + 512)


def test_parse_collectives_simple():
    out = parse_collectives(
        '%x = bf16[4,4]{1,0} all-gather(%y), dimensions={0}\n'
        '%z = f32[2]{0} all-reduce(%w), to_apply=%s\n')
    assert out["all-gather"] == 32
    assert out["all-reduce"] == 8
    assert out["total_weighted"] == 32 + 16


def test_roofline_terms_bottleneck():
    t = roofline_terms(197e12, 819e9, 0.0, HW_V5E)   # 1s compute, 1s mem
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    t2 = roofline_terms(1.0, 1.0, 500e9, HW_V5E)
    assert t2["bottleneck"] == "collective"
    assert t2["collective_s"] == pytest.approx(10.0)


def test_model_flops_moe_counts_active_only():
    dense = get_config("qwen3-8b")
    moe = get_config("mixtral-8x22b")
    shape = SHAPES["train_4k"]
    f_moe = model_flops(moe, shape, "train")
    # top-2 of 8 experts: active params far below total; a full-expert
    # count would be ~4x larger in the mlp term
    total_mlp = moe.moe.num_experts * 3 * moe.d_model * moe.d_ff
    active_mlp = moe.moe.top_k * 3 * moe.d_model * moe.d_ff
    assert active_mlp < total_mlp / 3
    assert f_moe > 0
    # decode counts one token per sequence
    f_dec = model_flops(dense, SHAPES["decode_32k"], "decode")
    f_train = model_flops(dense, shape, "train")
    assert f_dec < f_train / 1000


def test_sharding_policy_modes():
    import jax
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import ShardingPolicy, param_pspecs
    mesh = make_host_mesh()
    for mode in ("2d", "fsdp"):
        pol = ShardingPolicy(mesh, mode=mode)
        assert (pol.tp_axis is None) == (mode == "fsdp")
        params = {"layers": {"moe": {"wi0": jax.ShapeDtypeStruct(
            (8, 64, 128), "float32")}}}
        specs = param_pspecs(params, pol)  # must not raise
        assert specs["layers"]["moe"]["wi0"] is not None
    with pytest.raises(ValueError):
        ShardingPolicy(mesh, mode="bogus")
