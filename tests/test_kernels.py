"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

All kernels run in interpret mode on CPU — the kernel bodies execute in
Python with the exact BlockSpec tiling the TPU target will use.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.hetero_entropy import entropy_pallas
from repro.kernels.pairwise import pairwise_distance_pallas

DTYPES = [jnp.float32, jnp.bfloat16]


# ---------------------------------------------------------------------------
# hetero_entropy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,c", [(1, 4), (5, 10), (50, 1000), (17, 769),
                                 (8, 4096), (3, 151_936 // 64)])
def test_entropy_kernel_sweep(rng, n, c, dtype):
    x = jnp.asarray(rng.normal(size=(n, c)) * 0.02, dtype)
    got = entropy_pallas(x, 0.0025, interpret=True)
    want = ref.entropy_ref(x, 0.0025)
    tol = 5e-5 if dtype == jnp.float32 else 5e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_c", [128, 512, 2048])
def test_entropy_kernel_block_invariance(rng, block_c):
    """Result must not depend on the VMEM block size."""
    x = jnp.asarray(rng.normal(size=(9, 3000)), jnp.float32)
    got = entropy_pallas(x, 0.01, block_c=block_c, interpret=True)
    want = ref.entropy_ref(x, 0.01)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


def test_entropy_kernel_extreme_magnitudes(rng):
    """Online softmax must survive values that overflow a naive exp."""
    x = jnp.asarray(rng.normal(size=(4, 600)) * 500.0, jnp.float32)
    got = entropy_pallas(x, 0.0025, interpret=True)
    want = ref.entropy_ref(x, 0.0025)
    assert np.all(np.isfinite(np.asarray(got)))
    # at |u| ~ 2e5 f32 eps is ~0.016, so (u - m) carries O(eps·|u|)
    # rounding in ref and kernel alike; allow that inherent slack
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.05)


# ---------------------------------------------------------------------------
# pairwise (Eq. 9)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,c", [(4, 8), (50, 256), (130, 999), (7, 5)])
def test_pairwise_kernel_sweep(rng, n, c, dtype):
    x = jnp.asarray(rng.normal(size=(n, c)) * 0.02, dtype)
    h = ref.entropy_ref(x, 0.0025)
    norms = jnp.linalg.norm(x.astype(jnp.float32), axis=-1)
    got = pairwise_distance_pallas(x, norms, h, lam=10.0, interpret=True)
    want = ref.pairwise_distance_ref(x, h, 10.0)
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


def test_pairwise_kernel_symmetric_zero_diag(rng):
    x = jnp.asarray(rng.normal(size=(33, 100)), jnp.float32)
    h = ref.entropy_ref(x, 0.01)
    norms = jnp.linalg.norm(x, axis=-1)
    d = np.asarray(pairwise_distance_pallas(x, norms, h, interpret=True))
    np.testing.assert_allclose(d, d.T, atol=1e-4)
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,h,kv,dh,s", [
    (2, 8, 2, 64, 256),       # qwen2.5-style GQA 4:1
    (1, 16, 8, 128, 512),     # mixtral-style
    (2, 4, 4, 256, 128),      # gemma head_dim=256, MHA
    (3, 2, 1, 64, 96),        # MQA, ragged block
])
def test_decode_attention_sweep(rng, b, h, kv, dh, s, dtype):
    q = jnp.asarray(rng.normal(size=(b, h, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)), dtype)
    got = decode_attention_pallas(q, k, v, s, block_s=128, interpret=True)
    want = ref.decode_attention_ref(q, k, v, s)
    tol = 5e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


def test_decode_attention_ragged_lengths(rng):
    """Per-request cache lengths mask correctly."""
    b, h, kv, dh, s = 3, 8, 4, 64, 320
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    lens = np.array([1, 320, 130])
    got = decode_attention_pallas(q, k, v, lens, block_s=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)
    # length=1: output equals v[:, 0] exactly for that row
    np.testing.assert_allclose(
        np.asarray(got[0].reshape(kv, h // kv, dh)),
        np.asarray(jnp.broadcast_to(v[0, 0][:, None, :],
                                    (kv, h // kv, dh))),
        atol=1e-4)


def test_decode_attention_block_invariance(rng):
    b, h, kv, dh, s = 2, 4, 2, 64, 384
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    o1 = decode_attention_pallas(q, k, v, 300, block_s=64, interpret=True)
    o2 = decode_attention_pallas(q, k, v, 300, block_s=384, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------


def test_ops_dispatch_consistency(rng):
    x = jnp.asarray(rng.normal(size=(12, 300)) * 0.05, jnp.float32)
    e1 = ops.estimate_entropies(x, 0.0025, use_pallas=True)
    e2 = ops.estimate_entropies(x, 0.0025, use_pallas=False)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-4)
    d1 = ops.pairwise_distances(x, 0.0025, use_pallas=True)
    d2 = ops.pairwise_distances(x, 0.0025, use_pallas=False)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-3)
