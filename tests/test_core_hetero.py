"""Paper §3.2: heterogeneity estimation from output-layer updates.

Validates the analytical claims the method rests on:
  * Eq. 6 — E[Δb] is an affine image of the label distribution
  * Eq. 7 / Thm 3.3 — the tempered-softmax entropy of Δb orders clients
    consistently with the true label entropy
  * App. A.5 — privacy: (D, E) is not identifiable from E[Δb]
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import make_dirichlet_cohort
from repro.core import (delta_b_from_head_delta, estimate_entropy,
                        expected_bias_update, head_bias_update,
                        label_entropy, softmax_entropy)

TEMP = 0.0025


def test_expected_bias_update_eq6_structure(rng):
    """Eq. 6: Δb_i = ηR(D_i ΣE − E_i): sign structure of observations
    (1)-(2) in §3.2.1 — components for absent classes are negative."""
    C = 10
    d = np.zeros(C)
    d[3] = 1.0                      # all samples have label 3
    e = rng.uniform(0.01, 0.1, C)
    db = np.asarray(expected_bias_update(jnp.array(d), jnp.array(e),
                                         0.01, 2))
    assert db[3] > 0
    assert np.all(db[np.arange(C) != 3] < 0)


def test_eq6_affine_in_distribution(rng):
    """E[Δb] must be affine in D: Δb(aD1 + (1-a)D2) = aΔb(D1)+(1-a)Δb(D2)."""
    C = 7
    e = jnp.asarray(rng.uniform(0.01, 0.1, C))
    d1 = jnp.asarray(rng.dirichlet(np.ones(C)))
    d2 = jnp.asarray(rng.dirichlet(np.ones(C)))
    a = 0.3
    lhs = expected_bias_update(a * d1 + (1 - a) * d2, e, 0.01, 2)
    rhs = a * expected_bias_update(d1, e, 0.01, 2) \
        + (1 - a) * expected_bias_update(d2, e, 0.01, 2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-7)


def test_entropy_ordering_thm33(rng):
    """Clients with higher true label entropy get higher Ĥ (rank corr)."""
    dists, _ = make_dirichlet_cohort(rng, num_clients=60)
    e = jnp.full(10, 0.1)
    db = expected_bias_update(jnp.asarray(dists), e, 0.025, 2)
    h_hat = np.asarray(estimate_entropy(db, TEMP))
    h_true = np.asarray(label_entropy(jnp.asarray(dists)))
    # Spearman-ish: correlation of ranks
    r1 = np.argsort(np.argsort(h_hat)).astype(float)
    r2 = np.argsort(np.argsort(h_true)).astype(float)
    rho = np.corrcoef(r1, r2)[0, 1]
    assert rho > 0.9, rho


def test_balanced_vs_imbalanced_separation(rng):
    """The Thm 3.3 scenario: balanced clients dominate in Ĥ."""
    dists, n_imb = make_dirichlet_cohort(rng, num_clients=50)
    e = jnp.full(10, 0.1)
    db = expected_bias_update(jnp.asarray(dists), e, 0.025, 2)
    h_hat = np.asarray(estimate_entropy(db, TEMP))
    assert h_hat[n_imb:].min() > h_hat[:n_imb].max()


def test_privacy_underdetermined():
    """App. A.5: two different (D, E) pairs give identical E[Δb] — the
    server cannot invert the estimator to read label distributions."""
    C = 4
    d1 = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    e1 = jnp.asarray([0.05, 0.05, 0.05, 0.05])
    db1 = expected_bias_update(d1, e1, 0.01, 2)
    # pick (d2, e2) solving  d2_i * sum(e2) - e2_i = d1_i * sum(e1) - e1_i
    s2 = 0.4  # choose a different Σ e2
    d2 = jnp.asarray([0.35, 0.30, 0.20, 0.15])
    e2 = d2 * s2 - (d1 * jnp.sum(e1) - e1)
    assert jnp.all(e2 > 0) and abs(float(jnp.sum(e2)) - s2) < 1e-6
    db2 = expected_bias_update(d2, e2, 0.01, 2)
    np.testing.assert_allclose(np.asarray(db1), np.asarray(db2), atol=1e-7)
    assert not np.allclose(np.asarray(d1), np.asarray(d2))


@settings(deadline=None, max_examples=40)
@given(st.integers(2, 40), st.floats(1e-4, 10.0),
       st.integers(0, 2 ** 31 - 1))
def test_softmax_entropy_bounds(c, temp, seed):
    """0 <= H(softmax(v/T)) <= ln C for any v, T (property)."""
    r = np.random.default_rng(seed)
    v = jnp.asarray(r.normal(size=(5, c)) * r.uniform(0.001, 100))
    h = np.asarray(softmax_entropy(v, temp))
    assert np.all(h >= -1e-5)
    assert np.all(h <= np.log(c) + 1e-5)


@settings(deadline=None, max_examples=25)
@given(st.integers(3, 20), st.floats(0.01, 5.0),
       st.integers(0, 2 ** 31 - 1))
def test_softmax_entropy_shift_invariance(c, temp, seed):
    """H(softmax((v+const)/T)) == H(softmax(v/T))."""
    r = np.random.default_rng(seed)
    v = jnp.asarray(r.normal(size=(c,)))
    h0 = float(softmax_entropy(v, temp))
    h1 = float(softmax_entropy(v + 123.456, temp))
    assert abs(h0 - h1) < 1e-3


def test_uniform_input_max_entropy():
    v = jnp.zeros((3, 11))
    h = np.asarray(softmax_entropy(v, 0.1))
    np.testing.assert_allclose(h, np.log(11), atol=1e-6)


def test_head_weight_surrogate(rng):
    """ΔW row-mean surrogate preserves the Eq. 6 ordering (bias-free
    heads; DESIGN.md §5 beyond-paper extension)."""
    C, d = 10, 32
    dists, n_imb = make_dirichlet_cohort(rng, num_clients=20)
    e = np.full(C, 0.1)
    zbar = rng.uniform(0.5, 1.5, d)  # positive mean features
    h_hats = []
    for dist in dists:
        db = 0.025 * 2 * (dist * e.sum() - e)          # (C,)
        dW = np.outer(zbar, db)                        # (d, C)
        dW += rng.normal(0, 1e-5, dW.shape)
        pseudo = delta_b_from_head_delta(jnp.asarray(dW))
        h_hats.append(float(estimate_entropy(pseudo, TEMP)))
    h_hats = np.asarray(h_hats)
    assert h_hats[n_imb:].mean() > h_hats[:n_imb].mean() + 0.2


def test_head_bias_update_extraction():
    p0 = {"lm_head": {"w": jnp.zeros((4, 6)), "b": jnp.zeros(6)},
          "other": {"w": jnp.ones((2, 2))}}
    p1 = {"lm_head": {"w": jnp.ones((4, 6)), "b": jnp.arange(6.0)},
          "other": {"w": jnp.ones((2, 2))}}
    db = head_bias_update(p0, p1)
    np.testing.assert_allclose(np.asarray(db), np.arange(6.0))
    # bias-free head falls back to the ΔW surrogate
    q0 = {"lm_head": {"w": jnp.zeros((4, 6))}}
    q1 = {"lm_head": {"w": jnp.ones((4, 6))}}
    db2 = head_bias_update(q0, q1)
    np.testing.assert_allclose(np.asarray(db2), np.ones(6))
