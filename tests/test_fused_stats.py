"""Fused single-sweep stats kernel + the end-to-end selection step.

Awkward-shape sweeps run in interpret mode (the kernel body executes
with the exact BlockSpec tiling the TPU target will use) and are
checked against the pure-jnp oracles: ``ref.entropy_ref`` /
``jnp.linalg.norm`` for the stats, ``ref.selection_step_ref`` for the
fused pipeline.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fused_row_stats, hics_selection_step, ref
from repro.kernels.fused_stats import fused_stats_pallas
from repro.kernels.pairwise import hics_selection_step_pallas

# row block is 8, class block is 512 — shapes chosen to hit every
# padding corner: N not a multiple of the row block, C below / exactly
# at / above one class block, and the single-client edge
AWKWARD = [
    (13, 100),     # N % block_n != 0, C < one class block
    (8, 512),      # C exactly one block
    (5, 1000),     # C just under two blocks
    (1, 32),       # single client
    (9, 513),      # one element into the second class block
]


@pytest.mark.parametrize("n,c", AWKWARD)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_stats_awkward_shapes(rng, n, c, dtype):
    x = jnp.asarray(rng.normal(size=(n, c)) * 0.02, dtype)
    ent, norm, rms = fused_stats_pallas(x, 0.0025, interpret=True)
    want_ent = ref.entropy_ref(x, 0.0025)
    xf = x.astype(jnp.float32)
    want_norm = jnp.linalg.norm(xf, axis=-1)
    want_rms = jnp.sqrt(jnp.mean(jnp.square(xf), axis=-1))
    tol = 1e-5 if dtype == jnp.float32 else 5e-3
    np.testing.assert_allclose(np.asarray(ent), np.asarray(want_ent),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(norm), np.asarray(want_norm),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(rms), np.asarray(want_rms),
                               atol=tol, rtol=tol)


def test_fused_stats_block_invariance(rng):
    """Result must not depend on the VMEM block size."""
    x = jnp.asarray(rng.normal(size=(9, 3000)), jnp.float32)
    want = fused_stats_pallas(x, 0.01, block_c=512, interpret=True)
    for block_c in (128, 2048):
        got = fused_stats_pallas(x, 0.01, block_c=block_c, interpret=True)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       atol=1e-4)


def test_fused_stats_row_scale_matches_normalized_estimator(rng):
    """scale = 1/RMS must reproduce the normalize=True estimator."""
    x = jnp.asarray(rng.normal(size=(12, 700)) * 0.05, jnp.float32)
    _, _, rms = fused_stats_pallas(x, 0.0025, interpret=True)
    scale = 1.0 / jnp.clip(rms, 1e-12, None)
    ent, _, _ = fused_stats_pallas(x, 0.0025, row_scale=scale,
                                   interpret=True)
    want = ref.entropy_ref(x / jnp.clip(rms[:, None], 1e-12, None),
                           0.0025)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(want),
                               atol=1e-4)


def test_fused_stats_extreme_magnitudes(rng):
    """Online softmax must survive values that overflow a naive exp."""
    x = jnp.asarray(rng.normal(size=(4, 600)) * 500.0, jnp.float32)
    ent, norm, _ = fused_stats_pallas(x, 0.0025, interpret=True)
    assert np.all(np.isfinite(np.asarray(ent)))
    np.testing.assert_allclose(
        np.asarray(norm),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)


def test_fused_stats_vocab_scale(rng):
    """Acceptance shape: (64, 32768) vs the oracles, err < 1e-3."""
    x = jnp.asarray(rng.normal(size=(64, 32_768)) * 0.01, jnp.float32)
    ent, norm, rms = fused_stats_pallas(x, 0.0025, interpret=True)
    assert float(jnp.max(jnp.abs(ent - ref.entropy_ref(x, 0.0025)))) \
        < 1e-3
    assert float(jnp.max(jnp.abs(
        norm - jnp.linalg.norm(x, axis=-1)))) < 1e-3


# ---------------------------------------------------------------------------
# end-to-end selection step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c", [(5, 100), (13, 600), (32, 1024)])
@pytest.mark.parametrize("normalize", [False, True])
def test_selection_step_kernel_vs_oracle(rng, n, c, normalize):
    x = jnp.asarray(rng.normal(size=(n, c)) * 0.02, jnp.float32)
    ent, dist = hics_selection_step_pallas(x, 0.0025, lam=10.0,
                                           normalize=normalize,
                                           interpret=True)
    want_ent, want_dist = ref.selection_step_ref(x, 0.0025, 10.0,
                                                 normalize=normalize)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(want_ent),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(want_dist),
                               atol=5e-3)
    assert dist.shape == (n, n)
    # Eq. 9 self-distance is exactly zero
    np.testing.assert_allclose(np.asarray(jnp.diagonal(dist)), 0.0,
                               atol=1e-6)


def test_selection_step_bf16_gram(rng):
    """bf16 Gram operands, f32 accumulation: looser but bounded."""
    x = jnp.asarray(rng.normal(size=(24, 900)) * 0.02, jnp.float32)
    _, dist = hics_selection_step_pallas(x, 0.0025, lam=10.0,
                                         gram_in_bf16=True,
                                         interpret=True)
    _, want = ref.selection_step_ref(x, 0.0025, 10.0)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(want),
                               atol=2e-2)


def test_selection_step_dispatch_cpu(rng):
    """ops-level dispatch on CPU (jitted oracle) matches eager ref."""
    x = jnp.asarray(rng.normal(size=(10, 300)) * 0.02, jnp.float32)
    ent, dist = hics_selection_step(x, 0.0025, lam=10.0,
                                    use_pallas=False)
    want_ent, want_dist = ref.selection_step_ref(x, 0.0025, 10.0)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(want_ent),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(want_dist),
                               atol=1e-4)
    h, nrm, rms = fused_row_stats(x, 0.0025, use_pallas=False)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want_ent),
                               atol=1e-4)
