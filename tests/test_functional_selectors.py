"""Functional selector protocol: OO-shim/functional parity, purity,
and the device sampling/clustering primitives behind it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (SELECTORS, Observations, agglomerate,
                        agglomerate_device, cluster_means,
                        cluster_means_device, hierarchical_sample_device,
                        make_functional, make_selector,
                        weighted_sample_device)


def _drive_functional(name, n, k, t_max, c, seed, db, full, losses):
    """Replicate the shim's exact key discipline on the raw triple."""
    fn = make_functional(name, num_clients=n, num_select=k,
                         total_rounds=t_max, num_classes=c,
                         feat_dim=full.shape[-1])
    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    state = fn.init(k0)
    out = []
    for t in range(t_max):
        key, kt = jax.random.split(key)
        ids, state = fn.select(state, t, kt)
        ids_list = [int(i) for i in np.asarray(ids)]
        out.append(ids_list)
        obs = Observations(
            bias_updates=jnp.asarray(db[ids_list], jnp.float32),
            full_updates=jnp.asarray(
                full if "full_all" in fn.requires else full[ids_list],
                jnp.float32),
            losses=jnp.asarray(losses[t], jnp.float32))
        state = fn.update(state, t, ids, obs)
    return out, state


@pytest.mark.parametrize("name", sorted(SELECTORS))
def test_shim_functional_parity(name, rng):
    """N rounds through the OO shim and through the raw functional
    triple from the same seed produce identical participant sets."""
    n, k, t_max, c, seed = 24, 4, 10, 10, 11
    db = rng.normal(0, 0.02, (n, c))
    full = rng.normal(size=(n, 16))
    losses = rng.random((t_max, n))

    sel = make_selector(name, num_clients=n, num_select=k,
                        total_rounds=t_max, seed=seed)
    shim_ids = []
    for t in range(t_max):
        ids = sel.select(t)
        shim_ids.append(list(ids))
        sel.update(t, ids, bias_updates=db[ids],
                   full_updates=(full if "full_all" in sel.requires
                                 else full[ids]),
                   losses=losses[t])

    fn_ids, _ = _drive_functional(name, n, k, t_max, c, seed, db, full,
                                  losses)
    assert shim_ids == fn_ids


@pytest.mark.parametrize("name", sorted(SELECTORS))
def test_functional_transitions_are_pure(name, rng):
    """Same (state, t, key) twice -> same ids and same new state."""
    n, k, c = 16, 3, 8
    fn = make_functional(name, num_clients=n, num_select=k,
                         total_rounds=20, num_classes=c, feat_dim=c)
    state = fn.init(jax.random.PRNGKey(0))
    # push one observation through so warm branches have data
    ids0 = jnp.arange(k, dtype=jnp.int32)
    full_rows = n if "full_all" in fn.requires else k
    obs = Observations(bias_updates=jnp.asarray(rng.normal(size=(k, c)),
                                                jnp.float32),
                       full_updates=jnp.asarray(
                           rng.normal(size=(full_rows, c)), jnp.float32),
                       losses=jnp.asarray(rng.random(n), jnp.float32))
    state = fn.update(state, 0, ids0, obs)
    key = jax.random.PRNGKey(42)
    ids_a, state_a = fn.select(state, 5, key)
    ids_b, state_b = fn.select(state, 5, key)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    for la, lb in zip(jax.tree_util.tree_leaves(state_a),
                      jax.tree_util.tree_leaves(state_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.parametrize("name", sorted(SELECTORS))
def test_functional_select_jits_and_vmaps(name, rng):
    """select is jit-compatible, and vmaps over stacked states (the
    multi-seed sweep shape)."""
    n, k, c, b = 12, 3, 6, 4
    fn = make_functional(name, num_clients=n, num_select=k,
                         total_rounds=10, num_classes=c, feat_dim=c)
    jitted = jax.jit(fn.select)
    state = fn.init(jax.random.PRNGKey(0))
    ids, state = jitted(state, 0, jax.random.PRNGKey(1))
    assert np.asarray(ids).shape == (k,)
    # vmap over a batch of per-seed states
    states = jax.vmap(fn.init)(jax.random.split(jax.random.PRNGKey(2), b))
    keys = jax.random.split(jax.random.PRNGKey(3), b)
    ids_b, states_b = jax.vmap(lambda s, kk: fn.select(s, 0, kk))(states,
                                                                  keys)
    assert np.asarray(ids_b).shape == (b, k)
    for row in np.asarray(ids_b):
        assert len(set(row.tolist())) == k


@settings(deadline=None, max_examples=10)
@given(st.integers(6, 24), st.integers(1, 5), st.integers(2, 12),
       st.integers(0, 2**31 - 1))
def test_hics_parity_shape_sweep(n, k, c, seed):
    """Hypothesis sweep over (N, K, C): shim == functional for hics."""
    k = min(k, n)
    r = np.random.default_rng(seed)
    db = r.normal(0, 0.02, (n, c))
    full = r.normal(size=(n, 4))
    t_max = 6
    losses = r.random((t_max, n))
    sel = make_selector("hics", num_clients=n, num_select=k,
                        total_rounds=t_max, seed=seed % 997)
    shim_ids = []
    for t in range(t_max):
        ids = sel.select(t)
        shim_ids.append(list(ids))
        sel.update(t, ids, bias_updates=db[ids])
    fn_ids, _ = _drive_functional("hics", n, k, t_max, c, seed % 997,
                                  db, full, losses)
    assert shim_ids == fn_ids


@pytest.mark.parametrize("name", sorted(SELECTORS))
def test_num_select_clamped_to_num_clients(name):
    """num_select > num_clients selects all N (legacy behaviour)."""
    sel = make_selector(name, num_clients=4, num_select=9, total_rounds=6)
    ids = sel.select(0)
    assert sorted(ids) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Device primitives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("linkage", ["ward", "average", "complete",
                                     "single"])
def test_agglomerate_device_matches_numpy(linkage, rng):
    pts = rng.normal(size=(18, 3))
    d = np.linalg.norm(pts[:, None] - pts[None, :], axis=-1)
    for m in (2, 4, 9):
        a = agglomerate(d, m, linkage=linkage)
        b = np.asarray(agglomerate_device(jnp.asarray(d), m,
                                          linkage=linkage))
        np.testing.assert_array_equal(a, b)


def test_cluster_means_device_matches_numpy(rng):
    vals = rng.normal(size=20)
    labels = rng.integers(0, 4, 20)
    a = cluster_means(vals, labels, 4)
    b = np.asarray(cluster_means_device(jnp.asarray(vals),
                                        jnp.asarray(labels), 4))
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_weighted_sample_device_distribution():
    """Gumbel top-1 over log w reproduces ∝ w frequencies."""
    w = jnp.asarray([1.0, 2.0, 7.0])
    keys = jax.random.split(jax.random.PRNGKey(0), 4000)
    draws = jax.vmap(lambda k: weighted_sample_device(k, w, 1)[0])(keys)
    freq = np.bincount(np.asarray(draws), minlength=3) / 4000
    np.testing.assert_allclose(freq, np.asarray(w) / 10.0, atol=0.03)


def test_weighted_sample_device_distinct():
    w = jnp.ones(10)
    ids = weighted_sample_device(jax.random.PRNGKey(1), w, 10)
    assert sorted(np.asarray(ids).tolist()) == list(range(10))


def test_hierarchical_sample_device_two_stage():
    """Stage 1 prefers the high-entropy cluster; draws are distinct."""
    labels = jnp.asarray([0] * 20 + [1] * 5)
    means = jnp.asarray([0.1, 2.2])
    w = jnp.ones(25)
    keys = jax.random.split(jax.random.PRNGKey(0), 300)
    draws = jax.vmap(lambda k: hierarchical_sample_device(
        k, labels, means, w, 1, 4.0)[0])(keys)
    assert int(np.sum(np.asarray(draws) >= 20)) > 270
    # without-replacement exhaustion across clusters
    ids = hierarchical_sample_device(jax.random.PRNGKey(7), labels, means,
                                     w, 25, 1.0)
    assert sorted(np.asarray(ids).tolist()) == list(range(25))
