"""Substrate layers: losses, optimizers, checkpointing, data, sharding."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import latest_step, restore, save_pytree
from repro.data import SyntheticSpec, make_classification_data, \
    make_lm_streams, pad_and_stack
from repro.models.losses import chunked_lm_loss, classifier_loss
from repro.optim import adam, apply_updates, clip_by_global_norm, sgd, \
    sgd_momentum

# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def _direct_ce(x, w, b, targets, mask):
    logits = (x @ w).astype(jnp.float32)
    if b is not None:
        logits = logits + b
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    return float(((logz - tgt) * mask).sum() / mask.sum())


@pytest.mark.parametrize("s,chunk", [(8, 512), (64, 16), (60, 16), (17, 5)])
def test_chunked_lm_loss_matches_direct(rng, s, chunk):
    B, d, V = 3, 16, 50
    x = jnp.asarray(rng.normal(size=(B, s, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, V)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(V,)), jnp.float32)
    t = jnp.asarray(rng.integers(0, V, (B, s)), jnp.int32)
    m = jnp.asarray((rng.random((B, s)) > 0.2), jnp.float32)
    loss, metrics = chunked_lm_loss(x, w, b, t, m, chunk=chunk)
    assert float(loss) == pytest.approx(_direct_ce(x, w, b, t, m),
                                        rel=1e-5)
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


def test_classifier_loss_perfect_prediction():
    logits = jnp.asarray([[10.0, -10.0], [-10.0, 10.0]])
    labels = jnp.asarray([0, 1])
    loss, m = classifier_loss(logits, labels)
    assert float(loss) < 1e-6
    assert float(m["accuracy"]) == 1.0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_sgd_step_exact():
    opt = sgd(0.1)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([1.0, -1.0])}
    upd, _ = opt.update(g, opt.init(p))
    p2 = apply_updates(p, upd)
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.9, 2.1], atol=1e-7)


def test_momentum_accumulates():
    opt = sgd_momentum(0.1, momentum=0.9)
    p = {"w": jnp.zeros(1)}
    s = opt.init(p)
    g = {"w": jnp.ones(1)}
    u1, s = opt.update(g, s)
    u2, s = opt.update(g, s)
    assert abs(float(u2["w"][0])) > abs(float(u1["w"][0]))  # builds up


def test_adam_bias_correction_first_step():
    """First Adam step ≈ -lr * sign(g) regardless of g scale."""
    opt = adam(1e-3)
    for scale in (1e-6, 1.0, 1e6):
        p = {"w": jnp.zeros(1)}
        g = {"w": jnp.full(1, scale)}
        upd, _ = opt.update(g, opt.init(p), p)
        # eps=1e-8 shifts the g=1e-6 case by ~1% — that's correct Adam
        assert float(upd["w"][0]) == pytest.approx(-1e-3, rel=2e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 3.0), "b": jnp.full(9, 4.0)}   # norm = sqrt(36+144)
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(4 * 9 + 9 * 16))
    total = np.sqrt(sum(float(jnp.sum(x ** 2)) for x in
                        jax.tree_util.tree_leaves(clipped)))
    assert total == pytest.approx(1.0, rel=1e-5)
    # no-op when under the limit
    small = {"a": jnp.asarray([0.1])}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), [0.1], atol=1e-7)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(rng):
    tree = {
        "layer": {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(5,)), jnp.bfloat16)},
        "count": jnp.asarray(7, jnp.int32),
    }
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ckpt.npz")
        save_pytree(p, tree, step=42)
        restored, step = restore(p, tree)
        assert step == 42
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_latest_step(rng):
    tree = {"w": jnp.zeros(3)}
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        for s in (10, 5, 20):
            save_pytree(os.path.join(d, f"step_{s}.npz"), tree, step=s)
        assert latest_step(d).stem == "step_20"


def test_restore_shape_mismatch_raises(rng):
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "c.npz")
        save_pytree(p, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            restore(p, {"w": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_classification_data_separable(rng):
    spec = SyntheticSpec(num_classes=5, dim=32)
    x, y, protos = make_classification_data(rng, spec, 500)
    assert x.shape == (500, 32) and y.shape == (500,)
    # nearest-prototype classification must beat chance comfortably
    d = np.linalg.norm(x[:, None, :] - protos[None], axis=-1)
    acc = (d.argmin(1) == y).mean()
    assert acc > 0.5


def test_pad_and_stack(rng):
    xs = [rng.normal(size=(3, 4)).astype(np.float32),
          rng.normal(size=(7, 4)).astype(np.float32)]
    ys = [np.zeros(3, np.int32), np.ones(7, np.int32)]
    X, Y, M = pad_and_stack(xs, ys)
    assert X.shape == (2, 7, 4)
    assert M.sum() == 10
    np.testing.assert_array_equal(M[0], [1, 1, 1, 0, 0, 0, 0])


def test_lm_streams_topic_skew(rng):
    toks, mixes = make_lm_streams(rng, vocab=64, seq_len=32,
                                  num_clients=6, seqs_per_client=3,
                                  alphas=(0.05, 5.0))
    assert toks.shape == (6, 3, 32)
    assert toks.max() < 64
    np.testing.assert_allclose(mixes.sum(1), 1.0, atol=1e-9)
    # skewed group should have more concentrated mixtures
    conc_sharp = np.max(mixes[:3], axis=1).mean()
    conc_flat = np.max(mixes[3:], axis=1).mean()
    assert conc_sharp > conc_flat


# ---------------------------------------------------------------------------
# sharding policy (host mesh): divisibility fallbacks
# ---------------------------------------------------------------------------


def test_param_pspecs_divisibility():
    import jax.sharding as shd
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import ShardingPolicy, param_pspecs
    mesh = make_host_mesh()
    pol = ShardingPolicy(mesh)
    params = {"lm_head": {"w": jax.ShapeDtypeStruct((64, 256206),
                                                    jnp.float32)}}
    specs = param_pspecs(params, pol)
    # host mesh has axis size 1 — everything resolves (divisible by 1)
    assert isinstance(specs["lm_head"]["w"], shd.PartitionSpec)


def test_constrain_is_noop_without_policy():
    from repro.sharding import constrain
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
