import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# the single real CPU device; only repro.launch.dryrun forces 512.


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_dirichlet_cohort(rng, num_clients=30, num_classes=10,
                          alphas=(0.01, 10.0), frac_balanced=0.2):
    """Label distributions: (1-frac) imbalanced + frac balanced clients."""
    n_bal = int(num_clients * frac_balanced)
    n_imb = num_clients - n_bal
    dists = np.concatenate([
        np.stack([rng.dirichlet(np.full(num_classes, alphas[0]))
                  for _ in range(n_imb)]),
        np.stack([rng.dirichlet(np.full(num_classes, alphas[1]))
                  for _ in range(n_bal)]),
    ])
    return dists, n_imb
