import sys
import types

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# the single real CPU device; only repro.launch.dryrun forces 512.

try:  # property tests use hypothesis when available ...
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # ... and skip cleanly when it is absent.
    # Minimal stand-in: @given replaces the test with a no-argument
    # skipper (so pytest never looks for fixtures named after strategy
    # args), @settings is a pass-through, and every strategy constructor
    # returns an inert placeholder.
    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def _strategy_stub(name):
        def make(*_args, **_kwargs):
            return None
        make.__name__ = name
        return make

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = _strategy_stub  # PEP 562: any strategy name works
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(autouse=True, scope="module")
def _bounded_jax_caches():
    """Drop JAX's compiled-program caches when each module finishes.

    Every module compiles its own jit/scan/vmap programs; letting all
    of them stay live across the whole suite has crashed XLA:CPU's
    compiler late in the run (segfault inside ``backend_compile``).
    Module-internal caching — including the single-compile assertions —
    is unaffected; cross-module reuse just recompiles.
    """
    yield
    import jax

    jax.clear_caches()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_dirichlet_cohort(rng, num_clients=30, num_classes=10,
                          alphas=(0.01, 10.0), frac_balanced=0.2):
    """Label distributions: (1-frac) imbalanced + frac balanced clients."""
    n_bal = int(num_clients * frac_balanced)
    n_imb = num_clients - n_bal
    dists = np.concatenate([
        np.stack([rng.dirichlet(np.full(num_classes, alphas[0]))
                  for _ in range(n_imb)]),
        np.stack([rng.dirichlet(np.full(num_classes, alphas[1]))
                  for _ in range(n_bal)]),
    ])
    return dists, n_imb
