"""Selector API: HiCS-FL (Algorithm 1) + the five baselines."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_dirichlet_cohort
from repro.core import SELECTORS, expected_bias_update, make_selector

N, K, T = 40, 5, 100


def _db_matrix(rng, num_clients=N, scale=0.025):
    dists, n_imb = make_dirichlet_cohort(rng, num_clients=num_clients)
    e = jnp.full(10, 0.1)
    db = np.array(expected_bias_update(jnp.asarray(dists), e, scale, 2))
    db += rng.normal(0, 1e-5, db.shape)
    return db, n_imb


@pytest.mark.parametrize("name", sorted(SELECTORS))
def test_selects_k_distinct(name, rng):
    db, _ = _db_matrix(rng)
    sel = make_selector(name, num_clients=N, num_select=K, total_rounds=T)
    for t in range(6):
        ids = sel.select(t)
        assert len(ids) == K
        assert len(set(ids)) == K
        assert all(0 <= i < N for i in ids)
        sel.update(t, ids, bias_updates=db[ids],
                   full_updates=(db if "full_all" in sel.requires
                                 else db[ids]),
                   losses=rng.random(N))


def test_hics_coverage_sweep(rng):
    """Alg. 1 lines 14-15: first ⌈N/K⌉ rounds cover every client once."""
    db, _ = _db_matrix(rng)
    sel = make_selector("hics", num_clients=N, num_select=K,
                        total_rounds=T, seed=3)
    seen = set()
    for t in range(-(-N // K)):
        ids = sel.select(t)
        assert not (set(ids) & seen), "sweep must not repeat clients"
        seen |= set(ids)
        sel.update(t, ids, bias_updates=db[ids])
    assert seen == set(range(N))


def test_hics_prefers_balanced_clients(rng):
    """The paper's headline behaviour: clients with balanced data are
    sampled far more often while γ^t is large."""
    db, n_imb = _db_matrix(rng)
    sel = make_selector("hics", num_clients=N, num_select=K,
                        total_rounds=300, temperature=0.0025, gamma0=4.0)
    for t in range(-(-N // K)):
        ids = sel.select(t)
        sel.update(t, ids, bias_updates=db[ids])
    counts = np.zeros(N)
    for t in range(8, 60):
        ids = sel.select(t)
        counts[list(ids)] += 1
        sel.update(t, ids, bias_updates=db[ids])
    assert counts[n_imb:].mean() > 3 * max(counts[:n_imb].mean(), 0.1)


def test_hics_anneals_to_uniform(rng):
    """As γ^t → 0 cluster sampling becomes uniform (§3.4)."""
    db, n_imb = _db_matrix(rng)
    sel = make_selector("hics", num_clients=N, num_select=K,
                        total_rounds=100, temperature=0.0025, gamma0=4.0,
                        seed=1)
    for t in range(-(-N // K)):
        ids = sel.select(t)
        sel.update(t, ids, bias_updates=db[ids])
    counts = np.zeros(N)
    trials = 400
    for _ in range(trials):
        ids = sel.select(100)  # t = T ⇒ γ = 0
        counts[list(ids)] += 1
    # uniform over clusters — imbalanced clusters hold most clients, so
    # imbalanced clients must now receive a solid share of picks
    assert counts[:n_imb].sum() > 0.35 * counts.sum()


def test_powd_picks_highest_loss(rng):
    sel = make_selector("pow-d", num_clients=N, num_select=K,
                        total_rounds=T)
    losses = np.zeros(N)
    losses[[7, 13, 21, 33, 39]] = 10.0
    sel.update(0, list(range(K)), losses=losses)
    ids = sel.select(1)
    assert set(ids) == {7, 13, 21, 33, 39}


def test_divfl_spreads_over_gradient_space(rng):
    """Facility location must pick diverse clients, one per blob."""
    feats = np.concatenate([
        rng.normal(0, 0.01, (10, 8)) + np.eye(8)[i] * 5
        for i in range(4)
    ])
    sel = make_selector("divfl", num_clients=40, num_select=4,
                        total_rounds=T)
    sel.update(0, list(range(40)), full_updates=feats)
    ids = sel.select(1)
    blobs = {i // 10 for i in ids}
    assert len(blobs) == 4


def test_cs_warmup_then_clusters(rng):
    db, _ = _db_matrix(rng)
    sel = make_selector("cs", num_clients=N, num_select=K, total_rounds=T)
    seen = set()
    t = 0
    while len(seen) < N:
        ids = sel.select(t)
        seen |= set(ids)
        sel.update(t, ids, full_updates=db[ids])
        t += 1
        assert t < 3 * N / K, "warm-up must terminate"
    ids = sel.select(t)
    assert len(set(ids)) == K


def test_fedcor_runs_past_warmup(rng):
    sel = make_selector("fedcor", num_clients=N, num_select=K,
                        total_rounds=T, warmup=3)
    for t in range(8):
        ids = sel.select(t)
        assert len(set(ids)) == K
        sel.update(t, ids, losses=rng.random(N))


def test_selection_overhead_is_o_c(rng):
    """Table 3: HiCS-FL server compute is O(C), independent of |θ|.
    Feed CS/DivFL |θ|-sized features and HiCS C-sized features; HiCS
    must be far cheaper per round."""
    big = 50_000                      # |θ| stand-in
    C = 10
    db = rng.normal(size=(N, C))
    full = rng.normal(size=(N, big))
    hics = make_selector("hics", num_clients=N, num_select=K,
                         total_rounds=T)
    divfl = make_selector("divfl", num_clients=N, num_select=K,
                          total_rounds=T)
    for t in range(10):
        ids = hics.select(t)
        hics.update(t, ids, bias_updates=db[ids])
        jds = divfl.select(t)
        divfl.update(t, jds, full_updates=full)
    assert hics.update_seconds < divfl.update_seconds + 0.5
    # the Δb state is tiny: N x C f32 on device
    assert hics._delta_b.nbytes == N * C * 4
    assert hics.state.delta_b.shape == (N, C)


def test_unknown_selector_raises():
    with pytest.raises(KeyError):
        make_selector("nope", num_clients=4, num_select=1, total_rounds=2)
