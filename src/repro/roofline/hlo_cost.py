"""Trip-count-aware cost accounting over optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
so any scanned model (all of ours — layers are scanned) under-reports
FLOPs/bytes/collectives by the trip count (~25-80x). XLA:CPU annotates
every while with ``backend_config={"known_trip_count":{"n":...}}``; we
parse the computation graph, propagate execution counts through
while/fusion/call/conditional edges, and weight each op by its count.

Accounting rules (per partition — the SPMD module is per-chip):
* FLOPs: dot = 2 * |result| * contracted_size; convolution = 2 * |result| *
  (kernel_spatial * in_channels); elementwise transcendentals: |result|.
  Dots inside fusion computations are counted (they still execute).
* HBM bytes: sum of (operands + result) of top-level ops in the entry and
  while bodies, skipping no-traffic ops (parameter/tuple/gte/bitcast/
  constant). Ops inside fusions are NOT counted (fusion output/operands
  already are) — same convention as XLA's own bytes-accessed.
* Collectives: result bytes weighted by execution count, all-reduce
  weighted 2x (ring) for the wire-traffic total.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(|\{)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COMMENT_RE = re.compile(r"/\*.*?\*/")

_NO_TRAFFIC = {"parameter", "tuple", "get-tuple-element", "bitcast",
               "constant", "after-all", "iota"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "divide", "erf",
                   "exponential-minus-one", "log-plus-one", "atan2"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """All dtype[dims] tokens in a (possibly tuple) type: (elems, bytes)."""
    elems = byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elif f"{dt}[]" not in type_str:
            pass
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    line: str
    trip: Optional[int] = None
    calls: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion: bool = False


def _parse_operand_names(line: str) -> List[str]:
    m = _OPERANDS_RE.search(line[line.find("("):] if "(" in line else "")
    if not m:
        return []
    names = re.findall(r"%([\w\.\-]+)", m.group(1))
    return names


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw).rstrip()
        if not line:
            continue
        stripped = line.strip()
        if cur is None:
            # computation headers start at column 0 and end with "{"
            if (line.startswith(("%", "ENTRY")) and stripped.endswith("{")):
                m = _COMP_HDR_RE.match(stripped)
                if m:
                    name = m.group(1)
                    cur = Computation(name, [],
                                      is_fusion="fused" in name)
                    if stripped.startswith("ENTRY"):
                        entry = name
            continue
        if stripped == "}" or stripped.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, opcode = m.group(1), m.group(2), m.group(3)
        op = Op(name=name, opcode=opcode, result_type=rtype,
                operands=_parse_operand_names(stripped[stripped.find(opcode):]),
                line=stripped)
        tm = _TRIP_RE.search(stripped)
        if tm:
            op.trip = int(tm.group(1))
        if opcode == "while":
            for pat in (_CALLS_RE, _COND_RE):
                cm = pat.search(stripped)
                if cm:
                    op.calls.append(cm.group(1))
        elif opcode in ("fusion", "call", "map", "reduce", "reduce-window",
                        "scatter", "sort", "all-reduce", "reduce-scatter"):
            cm = _CALLS_RE.search(stripped)
            if cm:
                op.calls.append(cm.group(1))
        elif opcode == "conditional":
            bm = _BRANCHES_RE.search(stripped)
            if bm:
                op.calls.extend(re.findall(r"%([\w\.\-]+)", bm.group(1)))
        cur.ops.append(op)
    if cur is not None:
        comps[cur.name] = cur
    comps = {k: v for k, v in comps.items() if v is not None}
    return comps, entry


def _exec_counts(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """Fixpoint relaxation over the (DAG) call graph: count(callee) =
    sum over callers of count(caller) * trip_count(edge)."""
    edges = []  # (caller, callee, mult)
    for comp in comps.values():
        for op in comp.ops:
            if not op.calls:
                continue
            mult = float(op.trip) if (op.opcode == "while" and op.trip) else 1.0
            for callee in op.calls:
                edges.append((comp.name, callee, mult))
    counts: Dict[str, float] = defaultdict(float)
    for _ in range(64):  # call depth bound; converges much sooner
        new: Dict[str, float] = defaultdict(float)
        new[entry] = 1.0
        for caller, callee, mult in edges:
            new[callee] += new.get(caller, 0.0) * mult
        if new == counts:
            break
        counts = new
    return counts


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    _, out_b = _shape_elems_bytes(op.result_type)
    out_elems, _ = _shape_elems_bytes(op.result_type)
    lhs = shapes.get(op.operands[0], "") if op.operands else ""
    cm = _CONTRACT_RE.search(op.line)
    contract = 1
    if cm and lhs:
        sm = _SHAPE_RE.search(lhs)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            idxs = [int(i) for i in cm.group(1).split(",") if i]
            for i in idxs:
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(op.result_type)
    rhs = shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
    k = 1
    sm = _SHAPE_RE.search(rhs)
    if sm and sm.group(2):
        dims = [int(d) for d in sm.group(2).split(",")]
        k = 1
        for d in dims[:-1]:
            k *= d
    return 2.0 * out_elems * k


def analyze(text: str) -> Dict[str, float]:
    comps, entry = parse_module(text)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": {},
                "collective_total_weighted": 0.0, "parse_ok": False}
    counts = _exec_counts(comps, entry)
    # global shape table (names are effectively unique in optimized dumps)
    shapes: Dict[str, str] = {}
    for comp in comps.values():
        for op in comp.ops:
            shapes[op.name] = op.result_type

    flops = 0.0
    hbm = 0.0
    coll = defaultdict(float)
    for comp in comps.values():
        c = counts.get(comp.name, 0.0)
        if c == 0.0:
            continue
        for op in comp.ops:
            _, rbytes = _shape_elems_bytes(op.result_type)
            relems, _ = _shape_elems_bytes(op.result_type)
            if op.opcode == "dot":
                flops += c * _dot_flops(op, shapes)
            elif op.opcode == "convolution":
                flops += c * _conv_flops(op, shapes)
            elif op.opcode in _TRANSCENDENTAL:
                flops += c * relems
            if op.opcode in _COLLECTIVES or (
                    op.opcode.endswith("-start")
                    and op.opcode[:-6] in _COLLECTIVES):
                kind = op.opcode.replace("-start", "")
                coll[kind] += c * rbytes
            if not comp.is_fusion and op.opcode not in _NO_TRAFFIC \
                    and not op.opcode.endswith("-done"):
                ob = 0
                for o in op.operands:
                    t = shapes.get(o)
                    if t:
                        ob += _shape_elems_bytes(t)[1]
                hbm += c * (rbytes + ob)
    total_coll = sum(v * (2.0 if k == "all-reduce" else 1.0)
                     for k, v in coll.items())
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": dict(coll),
        "collective_total_weighted": total_coll,
        "parse_ok": True,
        "num_computations": len(comps),
    }
