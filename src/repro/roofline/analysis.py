"""Roofline analysis from compiled dry-run artifacts.

Three terms, in seconds, per chip (the SPMD module in
``compiled.as_text()`` is the per-partition program, so HLO sizes/FLOPs
from it are already per-chip):

  compute    = HLO_FLOPs_per_chip / peak_FLOPs
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

collective_bytes are NOT in cost_analysis: we parse the optimized HLO and
sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute. all-reduce counts 2x
(ring = reduce-scatter + all-gather). Shapes in the partitioned module are
local, so the sum approximates per-chip wire traffic.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# TPU v5e (per chip)
@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # bf16 FLOP/s
    hbm_bw: float              # bytes/s
    ici_bw: float              # bytes/s per link


HW_V5E = Hardware("tpu-v5e", 197e12, 819e9, 50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.7 = bf16[16,2048,688]{2,1,0} all-gather(...)
#        ROOT %tuple ... = (f32[...], ...) tuple(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind from partitioned HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(dtype, dims)
        out[kind] += b
    # all-reduce moves ~2x its size on a ring
    total = sum(v * (2.0 if k == "all-reduce" else 1.0)
                for k, v in out.items())
    out["total_weighted"] = total
    return out


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   collective_bytes_per_chip: float,
                   hw: Hardware = HW_V5E) -> Dict[str, float]:
    t_c = flops_per_chip / hw.peak_flops
    t_m = bytes_per_chip / hw.hbm_bw
    t_x = collective_bytes_per_chip / hw.ici_bw
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(t_c, t_m, t_x)
    terms["roofline_bound_s"] = total
    terms["compute_fraction"] = t_c / total if total > 0 else 0.0
    return terms


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) — the "useful" FLOPs
# ---------------------------------------------------------------------------


def _active_params(cfg) -> float:
    """Active parameter count per token (MoE counts top_k experts only)."""
    d, ff, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    dh = cfg.resolved_head_dim()
    n = V * d  # embedding
    if not cfg.tie_embeddings:
        n += d * V
    if cfg.kind in ("dense", "moe", "vlm"):
        attn = d * cfg.num_heads * dh + 2 * d * cfg.num_kv_heads * dh \
            + cfg.num_heads * dh * d
        gates = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        if cfg.moe is not None:
            mlp = cfg.moe.top_k * gates * d * ff + d * cfg.moe.num_experts
        else:
            mlp = gates * d * ff
        n += L * (attn + mlp)
    elif cfg.kind == "ssm":     # rwkv6
        n += L * (5 * d * d + 2 * d * ff + d * d)
    elif cfg.kind == "hybrid":
        from repro.models.mamba import dims as mdims
        d_inner, n_heads, conv_dim, d_in_proj = mdims(cfg)
        mamba = d * d_in_proj + d_inner * d
        n += L * mamba
        sites_attn = d * cfg.num_heads * dh * 2 + 2 * d * cfg.num_kv_heads * dh
        n += 14 * (sites_attn + 3 * d * ff)   # shared-block applications
    elif cfg.kind == "audio":
        attn = 2 * (d * cfg.num_heads * dh * 2 + 2 * d * cfg.num_kv_heads * dh)
        n += (L + cfg.encdec.encoder_layers) * (attn / 2 + 2 * d * ff)
    return float(n)


def model_flops(cfg, shape, mode: str) -> float:
    """6·N_active·D for train; 2·N_active·D for inference-forward."""
    n = _active_params(cfg)
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
