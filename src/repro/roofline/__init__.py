from repro.roofline.analysis import (
    HW_V5E,
    Hardware,
    model_flops,
    parse_collectives,
    roofline_terms,
)

__all__ = ["HW_V5E", "Hardware", "model_flops", "parse_collectives",
           "roofline_terms"]
