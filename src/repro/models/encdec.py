"""Encoder-decoder backbone for seamless-m4t-medium (audio → text).

The audio frontend (mel-spectrogram + conv feature extractor) is a STUB per
the assignment carve-out: the model consumes precomputed frame embeddings
``frames: (B, F, d_model)``. We implement the 12L bidirectional encoder and
the 12L causal decoder with cross-attention, vocab 256,206.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.losses import chunked_lm_loss
from repro.sharding import constrain, constrain_attn_q


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm),
        "attn": L.init_attention(ks[1], cfg),
        "ln2": L.init_norm(ks[2], cfg.d_model, cfg.norm),
        "mlp": L.init_mlp(ks[3], cfg.d_model, cfg.mlp == "gelu" and cfg.d_ff
                          or cfg.d_ff, cfg.mlp),
    }


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 6)
    return {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm),
        "attn": L.init_attention(ks[1], cfg),
        "ln_x": L.init_norm(ks[2], cfg.d_model, cfg.norm),
        "xattn": L.init_cross_attention(ks[3], cfg),
        "ln2": L.init_norm(ks[4], cfg.d_model, cfg.norm),
        "mlp": L.init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg.mlp),
    }


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.encdec.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    d = cfg.d_model
    return {
        "embed": 0.02 * jax.random.normal(ks[2], (cfg.vocab_size, d)),
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": L.init_norm(ks[3], d, cfg.norm),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": L.init_norm(ks[4], d, cfg.norm),
        "lm_head": {
            "w": L.dense_init(ks[5], (d, cfg.vocab_size)),
            **({"b": jnp.zeros((cfg.vocab_size,), jnp.float32)}
               if cfg.lm_head_bias else {}),
        },
    }


def encode(params, frames, cfg, *, q_chunk: int = 128):
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    x = constrain(frames, "batch", "seq", "embed")

    def body(carry, lp):
        h = L.apply_norm(carry, lp["ln1"], cfg.norm)
        positions = jnp.arange(carry.shape[1])[None, :]
        q, k, v = L._project_qkv(lp["attn"], h, cfg, positions)
        q = constrain_attn_q(q)
        a = L.full_attention(q, k, v, causal=False, q_chunk=q_chunk)
        a = a.reshape(carry.shape[0], carry.shape[1], -1)
        y = carry + a @ lp["attn"]["wo"].astype(carry.dtype)
        h = L.apply_norm(y, lp["ln2"], cfg.norm)
        return y + L.mlp_block(lp["mlp"], h, cfg.mlp), None

    x, _ = lax.scan(jax.checkpoint(body), x, params["encoder"])
    return L.apply_norm(x, params["enc_norm"], cfg.norm)


def decode_train(params, tokens, enc_out, cfg, *, q_chunk: int = 128,
                 collect_kv: bool = False):
    """Teacher-forced decoder pass. Returns (hidden, kv or None)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(enc_out.dtype)
    x = constrain(x, "batch", "seq", "embed")

    def body(carry, lp):
        h = L.apply_norm(carry, lp["ln1"], cfg.norm)
        positions = jnp.arange(carry.shape[1])[None, :]
        q, k, v = L._project_qkv(lp["attn"], h, cfg, positions)
        q = constrain_attn_q(q)
        a = L.full_attention(q, k, v, causal=True, q_chunk=q_chunk)
        a = a.reshape(carry.shape[0], carry.shape[1], -1)
        y = carry + a @ lp["attn"]["wo"].astype(carry.dtype)
        h = L.apply_norm(y, lp["ln_x"], cfg.norm)
        ek, ev = L.cross_kv(lp["xattn"], enc_out, cfg)
        y = y + L.cross_attention_block(lp["xattn"], h, ek, ev, cfg)
        h = L.apply_norm(y, lp["ln2"], cfg.norm)
        y = y + L.mlp_block(lp["mlp"], h, cfg.mlp)
        ys = (k, v, ek, ev) if collect_kv else None
        return y, ys

    x, kv = lax.scan(jax.checkpoint(body), x, params["decoder"])
    return L.apply_norm(x, params["final_norm"], cfg.norm), kv


def loss_fn(params, batch, cfg, *, dtype=jnp.float32, loss_chunk: int = 512):
    enc = encode(params, batch["frames"].astype(dtype), cfg)
    x, _ = decode_train(params, batch["tokens"], enc, cfg)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    loss, metrics = chunked_lm_loss(
        x, params["lm_head"]["w"], params["lm_head"].get("b"),
        batch["targets"], mask, chunk=loss_chunk)
    metrics["loss"] = loss
    return loss, metrics


def init_cache(cfg, batch: int, cache_len: int, source_len: int,
               dtype=jnp.bfloat16) -> dict:
    KV, dh = cfg.num_kv_heads, cfg.resolved_head_dim()
    Lyr = cfg.num_layers
    return {
        "k": jnp.zeros((Lyr, batch, cache_len, KV, dh), dtype),
        "v": jnp.zeros((Lyr, batch, cache_len, KV, dh), dtype),
        "xk": jnp.zeros((Lyr, batch, source_len, KV, dh), dtype),
        "xv": jnp.zeros((Lyr, batch, source_len, KV, dh), dtype),
    }


def prefill(params, batch, cfg, *, dtype=jnp.float32, cache_extra: int = 0):
    enc = encode(params, batch["frames"].astype(dtype), cfg)
    x, kv = decode_train(params, batch["tokens"], enc, cfg, collect_kv=True)
    logits = _head(params, x[:, -1:, :])
    k, v, ek, ev = kv
    if cache_extra:  # headroom for decode_step writes (self-attn only —
        pad = [(0, 0)] * k.ndim  # cross-attn K/V never grow)
        pad[2] = (0, cache_extra)
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    cache = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16),
             "xk": ek.astype(jnp.bfloat16), "xv": ev.astype(jnp.bfloat16)}
    return logits, cache


def decode_step(params, cache, batch, cfg, *, dtype=jnp.float32):
    """One decoder token against self-attn + cross-attn caches."""
    token, pos = batch["token"], batch["pos"]
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)

    def body(carry, xs):
        lp, kc, vc, xk, xv = xs
        h = L.apply_norm(carry, lp["ln1"], cfg.norm)
        a, (kc, vc) = L.attention_decode_block(lp["attn"], h, cfg, kc, vc,
                                               pos)
        y = carry + a
        h = L.apply_norm(y, lp["ln_x"], cfg.norm)
        y = y + L.cross_attention_block(lp["xattn"], h, xk.astype(dtype),
                                        xv.astype(dtype), cfg)
        h = L.apply_norm(y, lp["ln2"], cfg.norm)
        y = y + L.mlp_block(lp["mlp"], h, cfg.mlp)
        return y, (kc, vc)

    x, (ks, vs) = lax.scan(
        body, x,
        (params["decoder"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    return _head(params, x), {"k": ks, "v": vs,
                              "xk": cache["xk"], "xv": cache["xv"]}


def _head(params, x):
    logits = (x @ params["lm_head"]["w"].astype(x.dtype)).astype(jnp.float32)
    b = params["lm_head"].get("b")
    return logits + b if b is not None else logits
