"""Decoder-only transformer LM covering the dense, MoE and VLM assigned
architectures. Layers are stacked along a leading axis and executed with
``lax.scan`` + ``jax.checkpoint`` (compact HLO + bounded activation memory —
both matter for the 512-way SPMD dry-run on this 1-core container).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models.losses import chunked_lm_loss
from repro.sharding import constrain, constrain_attn_q


# ---------------------------------------------------------------------------
# Window / cache geometry
# ---------------------------------------------------------------------------


def effective_window(cfg, seq_len: int, long_context: bool) -> int:
    if long_context:
        if cfg.long_context_mode == "native":
            return cfg.sliding_window            # e.g. mixtral SWA
        if cfg.long_context_mode == "swa":
            return cfg.long_context_window
    return cfg.sliding_window


def cache_geometry(cfg, seq_len: int, long_context: bool) -> Tuple[int, bool]:
    """Returns (cache_len, ring). SWA decode uses a ring buffer of the
    window size — the sub-quadratic adaptation for long_500k (DESIGN §6)."""
    w = effective_window(cfg, seq_len, long_context)
    if w and w < seq_len:
        return w, True
    return seq_len, False


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm),
        "attn": L.init_attention(ks[1], cfg),
        "ln2": L.init_norm(ks[2], cfg.d_model, cfg.norm),
    }
    if cfg.moe is not None:
        p["moe"] = MOE.init_moe(ks[3], cfg.d_model, cfg.d_ff, cfg.moe)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp)
    return p


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    params = {
        "embed": L.embed_init(ks[1], (cfg.vocab_size, cfg.d_model)),
        "layers": stacked,
        "final_norm": L.init_norm(ks[2], cfg.d_model, cfg.norm),
    }
    head = {}
    if not cfg.tie_embeddings:
        head["w"] = L.dense_init(ks[3], (cfg.d_model, cfg.vocab_size))
    if cfg.lm_head_bias:
        head["b"] = jnp.zeros((cfg.vocab_size,), jnp.float32)
    if head:
        params["lm_head"] = head
    if cfg.vlm is not None:
        params["projector"] = {
            "w": L.dense_init(ks[4], (cfg.vlm.patch_embed_dim, cfg.d_model)),
            "b": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return params


def head_weights(params, cfg):
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"]["w"]
    b = params.get("lm_head", {}).get("b") if cfg.lm_head_bias else None
    return w, b


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg, dtype):
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return constrain(x, "batch", "seq", "embed")


def _layer_apply(lp, x, cfg, window, q_chunk):
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    positions = jnp.arange(x.shape[1])[None, :]
    q, k, v = L._project_qkv(lp["attn"], h, cfg, positions)
    q = constrain_attn_q(q)
    a = L.full_attention(q, k, v, causal=True, window=window, q_chunk=q_chunk)
    a = a.reshape(x.shape[0], x.shape[1], -1) @ lp["attn"]["wo"].astype(x.dtype)
    x = x + a
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    if cfg.moe is not None:
        m, aux = MOE.moe_block(lp["moe"], h, cfg.moe, cfg.mlp)
    else:
        m = L.mlp_block(lp["mlp"], constrain(h, "batch", "seq", "embed"),
                        cfg.mlp)
        aux = None
    return x + m, aux


def forward(params, tokens, cfg, *, extra_embeds=None, dtype=jnp.float32,
            window: Optional[int] = None, q_chunk: int = 128,
            collect_kv: bool = False):
    """Full-span forward. Returns (hidden (B,S,d), aux, kv or None).

    extra_embeds: (B, P, d_patch_or_frame) multimodal prefix (VLM), already
    embedded by the (stub) frontend; projected and prepended to the tokens.
    """
    if window is None:
        window = cfg.sliding_window
    x = _embed(params, tokens, cfg, dtype)
    if extra_embeds is not None:
        proj = params["projector"]
        pref = extra_embeds.astype(dtype) @ proj["w"].astype(dtype)
        pref = pref + proj["b"].astype(dtype)
        x = jnp.concatenate([pref, x], axis=1)
        x = constrain(x, "batch", "seq", "embed")

    def body(carry, lp):
        y, aux = _layer_apply(lp, carry, cfg, window, q_chunk)
        if collect_kv:
            # recompute K/V for the cache (cheap relative to the block)
            h = L.apply_norm(carry, lp["ln1"], cfg.norm)
            positions = jnp.arange(carry.shape[1])[None, :]
            _, k, v = L._project_qkv(lp["attn"], h, cfg, positions)
            return y, (aux, (k, v))
        return y, (aux, None)

    x, (aux, kv) = lax.scan(jax.checkpoint(body), x, params["layers"])
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    return x, aux, kv


def _aux_loss(aux) -> jnp.ndarray:
    if aux is None:
        return jnp.zeros(())
    return jnp.sum(aux["moe_lb_loss"]) + jnp.sum(aux["moe_z_loss"])


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------


def loss_fn(params, batch, cfg, *, dtype=jnp.float32, q_chunk: int = 128,
            loss_chunk: int = 512):
    tokens = batch["tokens"]
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    extra = batch.get("patches") if cfg.vlm is not None else None
    x, aux, _ = forward(params, tokens, cfg, extra_embeds=extra, dtype=dtype,
                        q_chunk=q_chunk)
    if extra is not None:
        x = x[:, -tokens.shape[1]:, :]      # loss over text positions only
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    w, b = head_weights(params, cfg)
    loss, metrics = chunked_lm_loss(x, w, b, targets, mask, chunk=loss_chunk)
    loss = loss + _aux_loss(aux)
    if aux is not None:
        metrics = dict(metrics,
                       moe_frac_dropped=jnp.mean(aux["moe_frac_dropped"]))
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16) -> dict:
    KV = cfg.num_kv_heads
    dh = cfg.resolved_head_dim()
    Lyr = cfg.num_layers
    return {
        "k": jnp.zeros((Lyr, batch, cache_len, KV, dh), dtype),
        "v": jnp.zeros((Lyr, batch, cache_len, KV, dh), dtype),
    }


def _pad_cache_seq(k, extra: int):
    """Append `extra` empty slots along the cache sequence axis (axis 2 of
    (L, B, S, KV, dh)) — decode_step writes NEW positions there; without
    headroom dynamic_update_slice clamps to S-1 and corrupts the cache."""
    if not extra:
        return k
    pad = [(0, 0)] * k.ndim
    pad[2] = (0, extra)
    return jnp.pad(k, pad)


def prefill(params, batch, cfg, *, dtype=jnp.float32, q_chunk: int = 128,
            cache_extra: int = 0):
    """Forward over a prompt; returns (last-token logits, cache).

    cache_extra: headroom slots for subsequent decode_step calls."""
    tokens = batch["tokens"]
    extra = batch.get("patches") if cfg.vlm is not None else None
    x, _, kv = forward(params, tokens, cfg, extra_embeds=extra, dtype=dtype,
                       q_chunk=q_chunk, collect_kv=True)
    w, b = head_weights(params, cfg)
    logits = x[:, -1:, :] @ w.astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if b is not None:
        logits = logits + b
    cache = {"k": _pad_cache_seq(kv[0].astype(jnp.bfloat16), cache_extra),
             "v": _pad_cache_seq(kv[1].astype(jnp.bfloat16), cache_extra)}
    return logits, cache


def decode_step(params, cache, batch, cfg, *, window: int = 0,
                ring: bool = False, dtype=jnp.float32):
    """One-token decode. batch: {'token': (B,1), 'pos': scalar int32}."""
    token, pos = batch["token"], batch["pos"]
    x = _embed(params, token, cfg, dtype)

    def body(carry, xs):
        lp, kc, vc = xs
        h = L.apply_norm(carry, lp["ln1"], cfg.norm)
        a, (kc, vc) = L.attention_decode_block(
            lp["attn"], h, cfg, kc, vc, pos, window=window, ring=ring)
        y = carry + a
        h = L.apply_norm(y, lp["ln2"], cfg.norm)
        if cfg.moe is not None:
            m, _ = MOE.moe_block(lp["moe"], h, cfg.moe, cfg.mlp)
        else:
            m = L.mlp_block(lp["mlp"], h, cfg.mlp)
        return y + m, (kc, vc)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    w, b = head_weights(params, cfg)
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if b is not None:
        logits = logits + b
    return logits, {"k": ks, "v": vs}
