"""RWKV6 ("Finch") — attention-free token mixer with data-dependent decay.

TPU adaptation: all per-token projections (r/k/v/g, the decay LoRA and the
token-shift LoRA) are computed for the whole sequence with batched matmuls
(MXU-friendly); only the WKV state recurrence runs under ``lax.scan``
(compact HLO: one loop regardless of T). Decode reuses the same step with a
persistent (state, shift) cache — O(1) memory in sequence length, which is
why rwkv6 runs long_500k natively.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, layer_norm
from repro.models.losses import chunked_lm_loss
from repro.sharding import constrain

_MIX = 5  # r, k, v, w, g


def init_tmix(key, d: int, rw) -> dict:
    r_mix, r_dec = rw.lora_rank_mix, rw.lora_rank_decay
    ks = jax.random.split(key, 12)
    return {
        "mu_x": jnp.zeros((d,), jnp.float32),
        "mu": jnp.zeros((_MIX, d), jnp.float32),
        "w1": dense_init(ks[0], (d, _MIX * r_mix), scale=0.01),
        "w2": dense_init(ks[1], (_MIX, r_mix, d), scale=0.01),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "decay_a": dense_init(ks[2], (d, r_dec), scale=0.01),
        "decay_b": dense_init(ks[3], (r_dec, d), scale=0.01),
        "receptance": dense_init(ks[4], (d, d)),
        "key": dense_init(ks[5], (d, d)),
        "value_ff": dense_init(ks[6], (d, d)),
        "gate": dense_init(ks[7], (d, d)),
        "wo": dense_init(ks[8], (d, d)),
        "bonus": jnp.zeros((d,), jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "gn_bias": jnp.zeros((d,), jnp.float32),
    }


def init_cmix(key, d: int, ff: int) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), jnp.float32),
        "mu_r": jnp.zeros((d,), jnp.float32),
        "key": dense_init(ks[0], (d, ff)),
        "value_out": dense_init(ks[1], (ff, d)),
        "receptance": dense_init(ks[2], (d, d)),
    }


def init_layer(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "ln1": {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)},
        "tmix": init_tmix(ks[0], d, cfg.rwkv),
        "ln2": {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)},
        "cmix": init_cmix(ks[1], d, cfg.d_ff),
    }


# ---------------------------------------------------------------------------
# Token-shift helpers
# ---------------------------------------------------------------------------


def _shift(x, x_prev):
    """x: (B,T,d); x_prev: (B,d) last token of the previous segment."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _tmix_projections(p, x, x_prev, n_heads: int, head_dim: int):
    """Vectorized r/k/v/w/g + decay for a whole segment."""
    B, T, d = x.shape
    xx = _shift(x, x_prev) - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    m = jnp.tanh(xxx @ p["w1"].astype(x.dtype))          # (B,T,5r)
    m = m.reshape(B, T, _MIX, -1)
    m = jnp.einsum("btmr,mrd->btmd", m, p["w2"].astype(x.dtype))
    cs = p["mu"].astype(x.dtype)[None, None] + m          # (B,T,5,d)
    xs = x[:, :, None, :] + xx[:, :, None, :] * cs        # (B,T,5,d)
    xr, xk, xv, xw, xg = [xs[:, :, i, :] for i in range(_MIX)]
    r = xr @ p["receptance"].astype(x.dtype)
    k = xk @ p["key"].astype(x.dtype)
    v = xv @ p["value_ff"].astype(x.dtype)
    g = jax.nn.silu(xg @ p["gate"].astype(x.dtype))
    dec = p["decay_base"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["decay_a"].astype(x.dtype)).astype(jnp.float32)
        @ p["decay_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dec))                            # (B,T,d) in (0,1)
    hd = head_dim
    shp = (B, T, n_heads, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            w.astype(jnp.float32).reshape(shp), g)


def _wkv_step(state, r, k, v, w, u):
    """One WKV step. state: (B,H,N,N) f32 [key-dim, value-dim]."""
    kv = k[..., :, None] * v[..., None, :]                # (B,H,N,N)
    y = jnp.einsum("bhn,bhnm->bhm", r, state + u[..., :, None] * kv)
    state = w[..., :, None] * state + kv
    return state, y


def tmix_apply(p, x, state, x_prev, n_heads: int, head_dim: int):
    """Time-mix over a segment. Returns (out, new_state, new_x_prev)."""
    B, T, d = x.shape
    r, k, v, w, g = _tmix_projections(p, x, x_prev, n_heads, head_dim)
    u = p["bonus"].astype(jnp.float32).reshape(n_heads, head_dim)

    def body(s, inp):
        rt, kt, vt, wt = inp
        s, y = _wkv_step(s, rt.astype(jnp.float32), kt.astype(jnp.float32),
                         vt.astype(jnp.float32), wt, u)
        return s, y

    seq = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
           v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state, ys = lax.scan(body, state, seq)
    y = ys.transpose(1, 0, 2, 3).reshape(B, T, d)         # (B,T,d) f32
    # per-head group norm
    yh = y.reshape(B, T, n_heads, head_dim)
    mu = yh.mean(-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, T, d) * p["gn_scale"] + p["gn_bias"]
    y = y.astype(x.dtype) * g
    return y @ p["wo"].astype(x.dtype), state, x[:, -1, :]


def cmix_apply(p, x, x_prev):
    xx = _shift(x, x_prev) - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["key"].astype(x.dtype)))
    k = constrain(k, "batch", "seq", "ff")
    kv = k @ p["value_out"].astype(x.dtype)
    return jax.nn.sigmoid(xr @ p["receptance"].astype(x.dtype)) * kv, x[:, -1, :]


def layer_apply(lp, x, state, xp_att, xp_ffn, cfg):
    H = cfg.d_model // cfg.rwkv.head_dim
    h = layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
    a, state, xp_att = tmix_apply(lp["tmix"], h, state, xp_att, H,
                                  cfg.rwkv.head_dim)
    x = x + a
    h = layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
    f, xp_ffn = cmix_apply(lp["cmix"], h, xp_ffn)
    return x + f, state, xp_att, xp_ffn


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    d = cfg.d_model
    return {
        "embed": 0.02 * jax.random.normal(ks[1], (cfg.vocab_size, d)),
        "ln_in": {"scale": jnp.ones((d,), jnp.float32),
                  "bias": jnp.zeros((d,), jnp.float32)},
        "layers": stacked,
        "final_norm": {"scale": jnp.ones((d,), jnp.float32),
                       "bias": jnp.zeros((d,), jnp.float32)},
        "lm_head": {
            "w": dense_init(ks[2], (d, cfg.vocab_size)),
            **({"b": jnp.zeros((cfg.vocab_size,), jnp.float32)}
               if cfg.lm_head_bias else {}),
        },
    }


def init_cache(cfg, batch: int, cache_len: int = 0, dtype=jnp.float32) -> dict:
    """Recurrent cache — O(1) in sequence length (cache_len unused)."""
    del cache_len
    H = cfg.d_model // cfg.rwkv.head_dim
    N = cfg.rwkv.head_dim
    Lyr = cfg.num_layers
    d = cfg.d_model
    return {
        "state": jnp.zeros((Lyr, batch, H, N, N), jnp.float32),
        "xp_att": jnp.zeros((Lyr, batch, d), dtype),
        "xp_ffn": jnp.zeros((Lyr, batch, d), dtype),
    }


def forward(params, tokens, cfg, cache=None, *, dtype=jnp.float32):
    """Segment forward (handles both full sequences and single tokens).

    Returns (hidden, new_cache)."""
    B, T = tokens.shape
    if cache is None:
        cache = init_cache(cfg, B, dtype=dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = constrain(x, "batch", "seq", "embed")
    x = layer_norm(x, params["ln_in"]["scale"], params["ln_in"]["bias"])

    def body(x, xs):
        lp, st, xa, xf = xs
        y, st, xa, xf = layer_apply(lp, x, st, xa, xf, cfg)
        return y.astype(x.dtype), (st, xa, xf)

    # token-shift caches follow the compute dtype inside the scan; cast
    # back to the cache's storage dtype on the way out so serve_step's
    # donated cache keeps a stable type across steps
    xa_dt, xf_dt = cache["xp_att"].dtype, cache["xp_ffn"].dtype
    x, (st, xa, xf) = lax.scan(
        jax.checkpoint(body), x,
        (params["layers"], cache["state"],
         cache["xp_att"].astype(dtype), cache["xp_ffn"].astype(dtype)))
    x = layer_norm(x, params["final_norm"]["scale"],
                   params["final_norm"]["bias"])
    return x, {"state": st, "xp_att": xa.astype(xa_dt),
               "xp_ffn": xf.astype(xf_dt)}


def loss_fn(params, batch, cfg, *, dtype=jnp.float32, loss_chunk: int = 512):
    x, _ = forward(params, batch["tokens"], cfg, dtype=dtype)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    loss, metrics = chunked_lm_loss(
        x, params["lm_head"]["w"], params["lm_head"].get("b"),
        batch["targets"], mask, chunk=loss_chunk)
    metrics["loss"] = loss
    return loss, metrics


def prefill(params, batch, cfg, *, dtype=jnp.float32, cache_extra: int = 0):
    del cache_extra  # recurrent cache is O(1) — no headroom needed
    x, cache = forward(params, batch["tokens"], cfg, dtype=dtype)
    logits = _head(params, x[:, -1:, :])
    return logits, cache


def decode_step(params, cache, batch, cfg, *, dtype=jnp.float32):
    x, cache = forward(params, batch["token"], cfg, cache, dtype=dtype)
    return _head(params, x), cache


def _head(params, x):
    logits = (x @ params["lm_head"]["w"].astype(x.dtype)).astype(jnp.float32)
    b = params["lm_head"].get("b")
    return logits + b if b is not None else logits
