"""Model registry: one uniform functional API over all assigned archs.

  api = get_model("qwen3-8b")
  params = api.init(rng)
  loss, metrics = api.loss(params, batch)
  logits, cache = api.prefill(params, batch)
  cache0 = api.init_cache(batch_size, seq_len, long_context=...)
  logits, cache = api.decode_step(params, cache, {"token": t, "pos": p})

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for every
model input of a given assigned input shape — used by the multi-pod
dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig, get_config
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import rwkv as RK
from repro.models import transformer as TF
from repro.models.transformer import cache_geometry, effective_window


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]


def _transformer_api(cfg) -> ModelApi:
    def init_cache(batch, seq_len, long_context=False, dtype=jnp.bfloat16):
        cache_len, _ = cache_geometry(cfg, seq_len, long_context)
        return TF.init_cache(cfg, batch, cache_len, dtype)

    def decode_step(params, cache, batch, *, long_context=False,
                    dtype=jnp.float32):
        w = effective_window(cfg, 1 << 62, long_context)
        cache_len = cache["k"].shape[2]
        ring = bool(w) and cache_len <= w
        return TF.decode_step(params, cache, batch, cfg, window=w, ring=ring,
                              dtype=dtype)

    return ModelApi(
        cfg=cfg,
        init=partial(TF.init_params, cfg=cfg),
        loss=partial(TF.loss_fn, cfg=cfg),
        prefill=partial(TF.prefill, cfg=cfg),
        decode_step=decode_step,
        init_cache=init_cache,
    )


def _rwkv_api(cfg) -> ModelApi:
    def init_cache(batch, seq_len, long_context=False, dtype=jnp.float32):
        del seq_len, long_context
        return RK.init_cache(cfg, batch, dtype=dtype)

    def decode_step(params, cache, batch, *, long_context=False,
                    dtype=jnp.float32):
        del long_context
        return RK.decode_step(params, cache, batch, cfg, dtype=dtype)

    return ModelApi(
        cfg=cfg,
        init=partial(RK.init_params, cfg=cfg),
        loss=partial(RK.loss_fn, cfg=cfg),
        prefill=partial(RK.prefill, cfg=cfg),
        decode_step=decode_step,
        init_cache=init_cache,
    )


def _hybrid_api(cfg) -> ModelApi:
    def init_cache(batch, seq_len, long_context=False, dtype=jnp.bfloat16):
        cache_len, _ = cache_geometry(cfg, seq_len, long_context)
        return HY.init_cache(cfg, batch, cache_len, dtype)

    def decode_step(params, cache, batch, *, long_context=False,
                    dtype=jnp.float32):
        w = effective_window(cfg, 1 << 62, long_context)
        cache_len = cache["k"].shape[2]
        ring = bool(w) and cache_len <= w
        return HY.decode_step(params, cache, batch, cfg, window=w, ring=ring,
                              dtype=dtype)

    def loss(params, batch, *, dtype=jnp.float32, **kw):
        return HY.loss_fn(params, batch, cfg, dtype=dtype,
                          window=cfg.sliding_window, **kw)

    return ModelApi(
        cfg=cfg,
        init=partial(HY.init_params, cfg=cfg),
        loss=loss,
        prefill=partial(HY.prefill, cfg=cfg),
        decode_step=decode_step,
        init_cache=init_cache,
    )


def _encdec_api(cfg) -> ModelApi:
    def init_cache(batch, seq_len, long_context=False, dtype=jnp.bfloat16):
        del long_context
        source = min(cfg.encdec.max_source_frames, seq_len)
        return ED.init_cache(cfg, batch, seq_len, source, dtype)

    def decode_step(params, cache, batch, *, long_context=False,
                    dtype=jnp.float32):
        del long_context
        return ED.decode_step(params, cache, batch, cfg, dtype=dtype)

    return ModelApi(
        cfg=cfg,
        init=partial(ED.init_params, cfg=cfg),
        loss=partial(ED.loss_fn, cfg=cfg),
        prefill=partial(ED.prefill, cfg=cfg),
        decode_step=decode_step,
        init_cache=init_cache,
    )


def get_model(cfg_or_name) -> ModelApi:
    cfg = (get_config(cfg_or_name) if isinstance(cfg_or_name, str)
           else cfg_or_name)
    if cfg.kind in ("dense", "moe", "vlm"):
        return _transformer_api(cfg)
    if cfg.kind == "ssm":
        return _rwkv_api(cfg)
    if cfg.kind == "hybrid":
        return _hybrid_api(cfg)
    if cfg.kind == "audio":
        return _encdec_api(cfg)
    raise ValueError(f"get_model does not handle kind={cfg.kind!r}; "
                     "classifier models use repro.models.classifier")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one assigned input shape."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.mode in ("train", "prefill"):
        if cfg.kind == "vlm":
            P = cfg.vlm.num_patches
            specs = {
                "patches": sds((B, P, cfg.vlm.patch_embed_dim), dtype),
                "tokens": sds((B, S - P), i32),
            }
            if shape.mode == "train":
                specs["targets"] = sds((B, S - P), i32)
                specs["loss_mask"] = sds((B, S - P), jnp.float32)
            return specs
        if cfg.kind == "audio":
            F = min(cfg.encdec.max_source_frames, S)
            specs = {
                "frames": sds((B, F, cfg.d_model), dtype),
                "tokens": sds((B, S), i32),
            }
            if shape.mode == "train":
                specs["targets"] = sds((B, S), i32)
                specs["loss_mask"] = sds((B, S), jnp.float32)
            return specs
        specs = {"tokens": sds((B, S), i32)}
        if shape.mode == "train":
            specs["targets"] = sds((B, S), i32)
            specs["loss_mask"] = sds((B, S), jnp.float32)
        return specs
    # decode: one new token against a seq_len cache
    return {"token": sds((B, 1), i32), "pos": sds((), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """Abstract cache pytree for decode shapes (eval_shape: no allocation)."""
    api = get_model(cfg)
    long_context = shape.name == "long_500k"
    return jax.eval_shape(
        lambda: api.init_cache(shape.global_batch, shape.seq_len,
                               long_context=long_context))


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and cfg.long_context_mode == "skip":
        return False
    if cfg.kind == "classifier":
        return False
    return True
