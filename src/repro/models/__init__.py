from repro.models.registry import (
    ModelApi,
    cache_specs,
    get_model,
    input_specs,
    supports_shape,
)

__all__ = ["ModelApi", "cache_specs", "get_model", "input_specs",
           "supports_shape"]
