"""Shared neural-net layers: norms, RoPE, attention (train/prefill/decode),
MLP variants. Pure-functional: params are nested dicts of jnp arrays.

Memory discipline: attention never materializes a (Tq, Tk) score tensor for
large Tq — the query axis is processed in chunks via ``lax.scan`` (lazy
softmax is unnecessary because each chunk sees the full, masked key axis;
the per-chunk score block is O(Cq * Tk) and bounded). Decode (Tq == 1)
attends against a (possibly ring-buffered) KV cache.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype=jnp.float32, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LM init)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(key, d, kind: str):
    del key
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)           # (head_dim // 2,)


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, dh); positions: broadcastable to (..., T)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)          # (dh//2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, dh//2)
    cos = jnp.cos(angles)[..., None, :]          # (..., T, 1, dh//2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_scores(q, k, scale):
    """q: (B, Tq, KV, G, dh), k: (B, Tk, KV, dh) -> (B, KV, G, Tq, Tk) f32."""
    return jnp.einsum("bqkgd,btkd->bkgqt", q, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_out(p, v):
    """p: (B, KV, G, Tq, Tk) f32, v: (B, Tk, KV, dh) -> (B, Tq, KV, G, dh)."""
    return jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)


def full_attention(q, k, v, *, causal: bool, window: int = 0,
                   q_offset=0, q_chunk: int = 128):
    """Attention for Tq > 1 (train / prefill).

    q: (B, Tq, H, dh); k, v: (B, Tk, KV, dh). Returns (B, Tq, H, dh).
    ``window > 0`` enables sliding-window masking (positions within
    [pos - window + 1, pos]). ``q_offset`` is the global position of q[0]
    relative to k[0] (0 for self-attention over the same span).
    """
    B, Tq, H, dh = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, Tq, KV, G, dh)
    kpos = jnp.arange(Tk)

    def attend(qc, qpos):
        s = _gqa_scores(qc, k, scale)            # (B, KV, G, Cq, Tk)
        if causal:
            m = qpos[:, None] + q_offset >= kpos[None, :]
            if window:
                m &= qpos[:, None] + q_offset < kpos[None, :] + window
            s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return _gqa_out(p, v).reshape(qc.shape[0], qc.shape[1], H, dh)

    if Tq <= q_chunk:
        return attend(qg, jnp.arange(Tq))

    if Tq % q_chunk:
        raise ValueError(f"Tq={Tq} not divisible by q_chunk={q_chunk}")
    nc = Tq // q_chunk
    qcs = qg.reshape(B, nc, q_chunk, KV, G, dh).transpose(1, 0, 2, 3, 4, 5)

    def body(_, xs):
        qc, start = xs
        return None, attend(qc, start + jnp.arange(q_chunk))

    starts = jnp.arange(nc) * q_chunk
    _, out = lax.scan(body, None, (qcs, starts))    # (nc, B, Cq, H, dh)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Tq, H, dh)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     ring: bool = False):
    """Single-token attention against a KV cache.

    q: (B, 1, H, dh); caches: (B, S, KV, dh); pos: scalar int32 — the global
    position of the current token (number of tokens already cached).

    With ``ring=True`` the cache is a ring buffer of size S covering the
    last S positions; validity masking accounts for wrap-around (slot order
    does not matter because RoPE is applied before caching).
    """
    B, _, H, dh = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, 1, KV, G, dh)
    s = _gqa_scores(qg, k_cache, scale)          # (B, KV, G, 1, S)
    slot = jnp.arange(S)
    if ring:
        valid = slot < jnp.minimum(pos + 1, S)   # filled slots
    else:
        valid = slot <= pos
        if window:
            valid &= slot > pos - window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v_cache).reshape(B, 1, H, dh)


def cache_update(k_cache, v_cache, k_new, v_new, pos, *, ring: bool = False):
    """Write k_new/v_new (B, 1, KV, dh) at position ``pos`` (ring: pos % S)."""
    S = k_cache.shape[1]
    idx = pos % S if ring else pos
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), idx, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), idx, axis=1)
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Attention block (projection + rope + attend)
# ---------------------------------------------------------------------------


def init_attention(key, cfg) -> dict:
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim()
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, H * dh)),
        "wk": dense_init(ks[1], (d, KV * dh)),
        "wv": dense_init(ks[2], (d, KV * dh)),
        "wo": dense_init(ks[3], (H * dh, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), jnp.float32)
        p["bk"] = jnp.zeros((KV * dh,), jnp.float32)
        p["bv"] = jnp.zeros((KV * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def _project_qkv(p, x, cfg, positions):
    B, T, _ = x.shape
    H, KV = cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim()
    q = jnp.einsum("btd,de->bte", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,de->bte", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,de->bte", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, T, H, dh)
    k = k.reshape(B, T, KV, dh)
    v = v.reshape(B, T, KV, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p, x, cfg, *, window: int = 0, q_chunk: int = 128,
                    positions=None, use_rope: bool = True):
    """Self-attention over x (train/prefill, full span). Returns (out, (k, v))."""
    B, T, _ = x.shape
    if positions is None and use_rope:
        positions = jnp.arange(T)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions if use_rope else None)
    out = full_attention(q, k, v, causal=True, window=window, q_chunk=q_chunk)
    out = out.reshape(B, T, -1) @ p["wo"].astype(x.dtype)
    return out, (k, v)


def attention_decode_block(p, x, cfg, k_cache, v_cache, pos, *,
                           window: int = 0, ring: bool = False,
                           use_rope: bool = True):
    """Single-token self-attention step. x: (B, 1, d). Returns (out, caches)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos) if use_rope else None
    q, k, v = _project_qkv(p, x, cfg, positions)
    k_cache, v_cache = cache_update(k_cache, v_cache, k, v, pos, ring=ring)
    out = decode_attention(q, k_cache, v_cache, pos, window=window, ring=ring)
    out = out.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return out, (k_cache, v_cache)


def init_cross_attention(key, cfg) -> dict:
    """Cross-attention: queries from decoder (d_model), keys from encoder."""
    return init_attention(key, cfg)


def cross_attention_block(p, x, enc_k, enc_v, cfg):
    """x: (B, Tq, d); enc_k/enc_v: (B, Tk, KV, dh) precomputed. No mask."""
    B, T, _ = x.shape
    H = cfg.num_heads
    dh = cfg.resolved_head_dim()
    q = jnp.einsum("btd,de->bte", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, T, H, dh)
    out = full_attention(q, enc_k, enc_v, causal=False)
    return out.reshape(B, T, H * dh) @ p["wo"].astype(x.dtype)


def cross_kv(p, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output."""
    B, Tk, _ = enc_out.shape
    KV = cfg.num_kv_heads
    dh = cfg.resolved_head_dim()
    k = jnp.einsum("btd,de->bte", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,de->bte", enc_out, p["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return k.reshape(B, Tk, KV, dh), v.reshape(B, Tk, KV, dh)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, ff: int, kind: str) -> dict:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"wi0": dense_init(ks[0], (d, ff)),
                "wi1": dense_init(ks[1], (d, ff)),
                "wo": dense_init(ks[2], (ff, d))}
    return {"wi0": dense_init(ks[0], (d, ff)),
            "wo": dense_init(ks[2], (ff, d))}


def mlp_block(p, x, kind: str):
    w0 = p["wi0"].astype(x.dtype)
    wo = p["wo"].astype(x.dtype)
    if kind == "swiglu":
        h = jax.nn.silu(x @ w0) * (x @ p["wi1"].astype(x.dtype))
    elif kind == "geglu":
        h = jax.nn.gelu(x @ w0) * (x @ p["wi1"].astype(x.dtype))
    else:  # gelu
        h = jax.nn.gelu(x @ w0)
    return h @ wo
