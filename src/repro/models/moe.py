"""Mixture-of-Experts block with capacity-based, gather/scatter dispatch.

Design notes (TPU adaptation):
* Dispatch is index-based (argsorted slots via cumsum-of-one-hot), NOT the
  dense one-hot einsum — so HLO FLOPs reflect only *active* expert compute
  (honest roofline: MODEL_FLOPS uses 6·N_active·D).
* Expert weights are laid out (E, d, ff); sharding is policy-dependent
  (repro/sharding.py): "2d" = ff tensor-parallel over 'model' (experts
  replicated — E ∈ {8, 32} ∤ 16), "fsdp" = d sharded over all axes,
  "ep" = experts over 'pod' (documented negative result, EXPERIMENTS
  §Perf iteration 7). Tokens stay batch-sharded; dispatch is a *vmapped*
  per-row scatter/gather so the batch dim partitions without cross-chip
  traffic (§Perf iteration 3).
* Overflowed tokens (beyond capacity) are dropped — slot C is a dump slot.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init
from repro.sharding import constrain


def init_moe(key, d: int, ff: int, moe_cfg) -> dict:
    E = moe_cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), scale=0.02),
        "wi0": dense_init(ks[1], (E, d, ff)),
        "wi1": dense_init(ks[2], (E, d, ff)),
        "wo": dense_init(ks[3], (E, ff, d)),
    }


def capacity(seq: int, moe_cfg) -> int:
    E, k, cf = moe_cfg.num_experts, moe_cfg.top_k, moe_cfg.capacity_factor
    return max(1, min(seq, int(math.ceil(seq * k / E * cf))))


def moe_block(p, x, moe_cfg, mlp_kind: str = "swiglu"
              ) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (y: (B, S, d), aux: losses + load stats).

    Routing groups are batch rows: capacity is per (row, expert).
    """
    B, S, d = x.shape
    E, K = moe_cfg.num_experts, moe_cfg.top_k
    C = capacity(S, moe_cfg)

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (B, S, E)
    top_w, top_i = lax.top_k(probs, K)                       # (B, S, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # --- slot assignment: position of each (token, k) in its expert queue.
    flat_e = top_i.reshape(B, S * K)                         # expert ids
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (B, S*K, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1                 # (B, S*K, E)
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < C                                           # (B, S*K)
    slot = jnp.where(keep, pos, C)                           # dump slot = C

    # --- scatter tokens into (B, E, C+1, d)
    # Dispatch is local to each batch row, so every tensor here is pinned
    # batch-sharded: without the constraints GSPMD bounces the expert
    # buffers between batch- and feature-sharded layouts around the
    # scatter/gather, paying full-tensor all-reduces per layer.
    xr = jnp.repeat(x, K, axis=1)                            # (B, S*K, d)
    xr = constrain(xr, "batch", None, None)

    def scatter_row(xr_row, e_row, s_row):
        z = jnp.zeros((E, C + 1, d), x.dtype)
        return z.at[e_row, s_row].set(xr_row, mode="drop")

    # vmapped per-row scatter -> the batch dim is a scatter *batching*
    # dim, which GSPMD partitions without cross-chip traffic
    buf = jax.vmap(scatter_row)(xr, flat_e, slot)
    buf = buf[:, :, :C, :]                                   # (B, E, C, d)
    buf = constrain(buf, "batch", "expert", None, None)

    # --- expert FFN (tensor-parallel over ff via weight sharding)
    w0 = p["wi0"].astype(x.dtype)
    wo = p["wo"].astype(x.dtype)
    h = jnp.einsum("becd,edf->becf", buf, w0)
    if mlp_kind == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", buf,
                                        p["wi1"].astype(x.dtype))
    elif mlp_kind == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("becd,edf->becf", buf,
                                        p["wi1"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("becf,efd->becd", h, wo)                # (B, E, C, d)
    out = constrain(out, "batch", "expert", None, None)

    # --- gather back and combine with router weights
    gslot = jnp.minimum(slot, C - 1)
    gathered = jax.vmap(lambda o, e, s: o[e, s])(out, flat_e, gslot)
    gathered = constrain(gathered, "batch", None, None)       # (B, S*K, d)
    w = (top_w.reshape(B, S * K) * keep.astype(jnp.float32))
    y = (gathered.astype(jnp.float32) * w[..., None])
    y = y.reshape(B, S, K, d).sum(axis=2).astype(x.dtype)

    # --- aux losses (Switch-style load balance + router z-loss)
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {
        "moe_lb_loss": moe_cfg.load_balance_loss * lb_loss,
        "moe_z_loss": moe_cfg.router_z_loss * z_loss,
        "moe_frac_dropped": frac_dropped,
        "moe_expert_load": me,
    }
    return y, aux
