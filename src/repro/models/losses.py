"""Loss functions. The LM cross-entropy is sequence-chunked so the
(B, S, V) logits tensor is never materialized at full length — critical for
vocab sizes up to 256k at 1M-token global batches (train_4k).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _chunk_ce(x, head_w, head_b, targets, mask):
    """x: (B, C, d) hidden; returns (sum_loss, sum_count, sum_correct)."""
    logits = jnp.einsum("bcd,dv->bcv", x, head_w.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if head_b is not None:
        logits = logits + head_b.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)                    # (B, C)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (logz - tgt) * mask
    correct = (jnp.argmax(logits, axis=-1) == targets) * mask
    return ce.sum(), mask.sum(), correct.sum()


def chunked_lm_loss(x, head_w, head_b, targets, mask,
                    chunk: int = 512) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d); head_w: (d, V); targets/mask: (B, S).

    Scans over sequence chunks; each chunk's logits are transient (and
    vocab-sharded on the mesh), so peak memory is O(B * chunk * V / chips).
    """
    B, S, d = x.shape
    mask = mask.astype(jnp.float32)
    if S <= chunk:
        tot, cnt, cor = _chunk_ce(x, head_w, head_b, targets, mask)
    else:
        if S % chunk:
            # fall back to the largest divisor chunk
            while S % chunk:
                chunk -= 1
        nc = S // chunk
        xs = (x.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3),
              targets.reshape(B, nc, chunk).transpose(1, 0, 2),
              mask.reshape(B, nc, chunk).transpose(1, 0, 2))

        def body(carry, inp):
            xc, tc, mc = inp
            t, c, r = _chunk_ce(xc, head_w, head_b, tc, mc)
            tot, cnt, cor = carry
            return (tot + t, cnt + c, cor + r), None

        (tot, cnt, cor), _ = lax.scan(
            body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), xs)
    denom = jnp.maximum(cnt, 1.0)
    loss = tot / denom
    return loss, {"ce_loss": loss, "accuracy": cor / denom, "tokens": cnt}


def classifier_loss(logits, labels) -> Tuple[jnp.ndarray, dict]:
    """Plain CE over one-hot labels (the paper's Eq. 13)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - tgt)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, {"ce_loss": loss, "accuracy": acc}
