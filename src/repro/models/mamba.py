"""Mamba2 (SSD) mixer — chunked-scan formulation.

TPU adaptation: instead of a per-token recurrence (bandwidth-bound, no MXU
use), the sequence is split into chunks of Q tokens. Within a chunk the SSD
is an attention-like masked matmul (MXU); across chunks a short
``lax.scan`` carries the (H, P, N) state. This is the standard
"state-space duality" form, with memory O(B * H * Q * Q) per chunk block —
heads shard over the 'model' mesh axis (112 heads % 16 == 0 for zamba2-7b).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init
from repro.sharding import constrain

NGROUPS = 1  # shared B/C across heads (zamba2 setting)


def dims(cfg):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * NGROUPS * ssm.state_dim
    d_in_proj = 2 * d_inner + 2 * NGROUPS * ssm.state_dim + n_heads
    return d_inner, n_heads, conv_dim, d_in_proj


def init_layer(key, cfg) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim, d_in_proj = dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "ln": {"scale": jnp.zeros((d,), jnp.float32)},
        "in_proj": dense_init(ks[0], (d, d_in_proj)),
        "conv_w": 0.1 * jax.random.normal(ks[1], (conv_dim, ssm.conv_width)),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "gn_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_inner, d)),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv. x: (B,T,C); w: (C,W). Returns (y, new_state).

    conv_state: (B, W-1, C) trailing inputs from the previous segment."""
    B, T, C = x.shape
    W = w.shape[1]
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)         # (B, T+W-1, C)
    y = jnp.zeros((B, T, C), x.dtype)
    for i in range(W):
        y = y + xp[:, i:i + T, :] * w[:, i].astype(x.dtype)
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(W - 1):, :] if W > 1 else conv_state
    return jax.nn.silu(y), new_state


def _split_proj(zxbcdt, cfg):
    ssm = cfg.ssm
    d_inner, n_heads, conv_dim, _ = dims(cfg)
    N = ssm.state_dim
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xBC, dt


def _split_xbc(xBC, cfg):
    ssm = cfg.ssm
    d_inner, n_heads, _, _ = dims(cfg)
    N = ssm.state_dim
    x = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + N]
    Cm = xBC[..., d_inner + N:]
    return x, Bm, Cm


def ssd_chunked(x, a_log_t, Bm, Cm, dt, ssm, state=None):
    """Chunked SSD scan.

    x: (B,T,H,P); a_log_t: (B,T,H) per-token log-decay (negative);
    Bm, Cm: (B,T,N); dt: (B,T,H); state: (B,H,P,N) carry or None.
    Returns (y: (B,T,H,P), final_state).
    """
    B, T, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(ssm.chunk, T)
    if T % Q:
        raise ValueError(f"T={T} not divisible by chunk={Q}")
    nc = T // Q
    if state is None:
        state = jnp.zeros((B, H, Pd, N), jnp.float32)

    xc = x.reshape(B, nc, Q, H, Pd)
    ac = a_log_t.reshape(B, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)
    dtc = dt.reshape(B, nc, Q, H)

    La = jnp.cumsum(ac, axis=2)                           # (B,nc,Q,H)
    # intra-chunk: scores[q,s] = exp(La[q]-La[s]) * (C_q . B_s) * dt_s, s<=q
    G = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc,
                   preferred_element_type=jnp.float32)    # (B,nc,Q,Q)
    decay = La[:, :, :, None, :] - La[:, :, None, :, :]   # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, -jnp.inf)
    M = jnp.exp(decay)
    scores = G[..., None] * M * dtc[:, :, None, :, :]     # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", scores,
                         xc.astype(jnp.float32))

    # chunk states: sum_s exp(La[end]-La[s]) dt_s (x_s B_s^T)
    dte = jnp.exp(La[:, :, -1:, :] - La) * dtc            # (B,nc,Q,H)
    cstate = jnp.einsum("bcqh,bcqhp,bcqn->bchpn",
                        dte, xc.astype(jnp.float32), Bc)  # (B,nc,H,P,N)
    a_chunk = jnp.exp(La[:, :, -1, :])                    # (B,nc,H)

    def body(s, inp):
        cs, ak, Ck, Lk = inp
        # inter-chunk contribution reads the *incoming* state
        y_in = jnp.einsum("bqn,bqh,bhpn->bqhp", Ck, jnp.exp(Lk), s)
        s = ak[..., None, None] * s + cs
        return s, y_in

    seq = (cstate.transpose(1, 0, 2, 3, 4), a_chunk.transpose(1, 0, 2),
           Cc.transpose(1, 0, 2, 3), La.transpose(1, 0, 2, 3))
    state, y_inter = lax.scan(body, state, seq)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)            # (B,nc,Q,H,P)
    y = (y_intra + y_inter).reshape(B, T, H, Pd)
    return y, state


def mixer_apply(lp, x, cfg, cache=None):
    """x: (B,T,d). cache: None or {'state': (B,H,P,N), 'conv': (B,W-1,C)}.
    Returns (out, new_cache)."""
    ssm = cfg.ssm
    B, T, d = x.shape
    d_inner, n_heads, conv_dim, _ = dims(cfg)
    Pd = ssm.head_dim
    zxbcdt = x @ lp["in_proj"].astype(x.dtype)
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    conv_state = cache["conv"] if cache is not None else None
    xBC, conv_state = _causal_conv(xBC, lp["conv_w"], lp["conv_b"],
                                   conv_state)
    xs, Bm, Cm = _split_xbc(xBC, cfg)
    xs = xs.reshape(B, T, n_heads, Pd)
    xs = constrain(xs, "batch", "seq", "heads", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])  # (B,T,H)
    a_log_t = -dt * jnp.exp(lp["a_log"])                  # (B,T,H), negative
    state = cache["state"] if cache is not None else None
    y, state = ssd_chunked(xs, a_log_t, Bm, Cm, dt, ssm, state)
    y = y + lp["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_inner)
    # gated RMSNorm
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * lax.rsqrt(var + 1e-6) * lp["gn_scale"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ lp["out_proj"].astype(x.dtype)
    return out, {"state": state, "conv": conv_state}


def init_cache_layer(cfg, batch: int, dtype=jnp.float32) -> dict:
    ssm = cfg.ssm
    d_inner, n_heads, conv_dim, _ = dims(cfg)
    return {
        "state": jnp.zeros((batch, n_heads, ssm.head_dim, ssm.state_dim),
                           jnp.float32),
        "conv": jnp.zeros((batch, ssm.conv_width - 1, conv_dim), dtype),
    }
