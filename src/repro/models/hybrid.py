"""Zamba2-style hybrid: Mamba2 backbone + periodically applied *shared*
(weight-tied) attention blocks (two alternating shared blocks).

Layout: before every ``attn_period``-th mamba layer, the shared transformer
block (attention + MLP) for ``site % num_shared_blocks`` is applied. Weights
are shared across sites but each site keeps its own KV cache at decode time.
The mamba stack is scanned in per-group chunks so HLO stays compact while
FLOPs remain honest (no lax.cond double-counting).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import mamba as M
from repro.models.losses import chunked_lm_loss
from repro.sharding import constrain


def group_sizes(cfg) -> List[int]:
    period = cfg.hybrid.attn_period
    n, out = cfg.num_layers, []
    while n > 0:
        out.append(min(period, n))
        n -= period
    return out


def num_attn_sites(cfg) -> int:
    return len(group_sizes(cfg))


def _init_shared_block(key, cfg):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(ks[0], cfg.d_model, cfg.norm),
        "attn": L.init_attention(ks[1], cfg),
        "ln2": L.init_norm(ks[2], cfg.d_model, cfg.norm),
        "mlp": L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp),
    }


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 5)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    mamba_stack = jax.vmap(lambda k: M.init_layer(k, cfg))(layer_keys)
    shared_keys = jax.random.split(ks[1], cfg.hybrid.num_shared_blocks)
    shared = jax.vmap(lambda k: _init_shared_block(k, cfg))(shared_keys)
    d = cfg.d_model
    return {
        "embed": 0.02 * jax.random.normal(ks[2], (cfg.vocab_size, d)),
        "mamba": mamba_stack,
        "shared": shared,
        "final_norm": L.init_norm(ks[3], d, cfg.norm),
        "lm_head": {
            "w": L.dense_init(ks[4], (d, cfg.vocab_size)),
            **({"b": jnp.zeros((cfg.vocab_size,), jnp.float32)}
               if cfg.lm_head_bias else {}),
        },
    }


def _shared_site_params(params, site: int, cfg):
    idx = site % cfg.hybrid.num_shared_blocks
    return jax.tree_util.tree_map(lambda a: a[idx], params["shared"])


def _slice_stack(stack, start: int, size: int):
    return jax.tree_util.tree_map(lambda a: a[start:start + size], stack)


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------


def forward(params, tokens, cfg, *, dtype=jnp.float32, window: int = 0,
            q_chunk: int = 128, collect_cache: bool = False):
    """Returns (hidden, cache or None). cache: {'kv': [(k,v)...] per site,
    'mamba': list of per-group stacked mamba caches}."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = constrain(x, "batch", "seq", "embed")
    kv_sites, mamba_caches = [], []

    def mamba_scan_body(carry, lp):
        h = L.rms_norm(carry, lp["ln"]["scale"])
        out, cache = M.mixer_apply(lp, h, cfg, None)
        y = carry + out
        return y, cache if collect_cache else None

    start = 0
    for site, gs in enumerate(group_sizes(cfg)):
        sp = _shared_site_params(params, site, cfg)
        h = L.apply_norm(x, sp["ln1"], cfg.norm)
        a, (k, v) = L.attention_block(sp["attn"], h, cfg, window=window,
                                      q_chunk=q_chunk)
        x = x + a
        h = L.apply_norm(x, sp["ln2"], cfg.norm)
        x = x + L.mlp_block(sp["mlp"], h, cfg.mlp)
        if collect_cache:
            kv_sites.append((k, v))
        group = _slice_stack(params["mamba"], start, gs)
        x, mc = lax.scan(jax.checkpoint(mamba_scan_body), x, group)
        if collect_cache:
            mamba_caches.append(mc)
        start += gs
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    cache = None
    if collect_cache:
        cache = {"kv": kv_sites, "mamba": mamba_caches}
    return x, cache


def loss_fn(params, batch, cfg, *, dtype=jnp.float32, window: int = 0,
            loss_chunk: int = 512):
    x, _ = forward(params, batch["tokens"], cfg, dtype=dtype, window=window)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    loss, metrics = chunked_lm_loss(
        x, params["lm_head"]["w"], params["lm_head"].get("b"),
        batch["targets"], mask, chunk=loss_chunk)
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16) -> dict:
    sites = num_attn_sites(cfg)
    KV, dh = cfg.num_kv_heads, cfg.resolved_head_dim()
    mamba = jax.vmap(lambda _: M.init_cache_layer(cfg, batch, dtype))(
        jnp.arange(cfg.num_layers))
    return {
        "k": jnp.zeros((sites, batch, cache_len, KV, dh), dtype),
        "v": jnp.zeros((sites, batch, cache_len, KV, dh), dtype),
        "mamba": mamba,
    }


def prefill(params, batch, cfg, *, dtype=jnp.float32, window: int = 0,
            q_chunk: int = 128, cache_extra: int = 0):
    x, cache = forward(params, batch["tokens"], cfg, dtype=dtype,
                       window=window, q_chunk=q_chunk, collect_cache=True)
    logits = _head(params, x[:, -1:, :])
    ks = jnp.stack([k for k, _ in cache["kv"]]).astype(jnp.bfloat16)
    vs = jnp.stack([v for _, v in cache["kv"]]).astype(jnp.bfloat16)
    if cache_extra:  # decode headroom (see transformer._pad_cache_seq)
        pad = [(0, 0)] * ks.ndim
        pad[2] = (0, cache_extra)
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    groups = cache["mamba"]
    mamba = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *groups)
    return logits, {"k": ks, "v": vs, "mamba": mamba}


def decode_step(params, cache, batch, cfg, *, window: int = 0,
                ring: bool = False, dtype=jnp.float32):
    token, pos = batch["token"], batch["pos"]
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)
    new_k, new_v = [], []
    mamba_out = []

    def mamba_step_body(carry, xs):
        lp, lc = xs
        h = L.rms_norm(carry, lp["ln"]["scale"])
        out, lc = M.mixer_apply(lp, h, cfg, lc)
        return carry + out, lc

    start = 0
    for site, gs in enumerate(group_sizes(cfg)):
        sp = _shared_site_params(params, site, cfg)
        h = L.apply_norm(x, sp["ln1"], cfg.norm)
        a, (kc, vc) = L.attention_decode_block(
            sp["attn"], h, cfg, cache["k"][site], cache["v"][site], pos,
            window=window, ring=ring)
        new_k.append(kc)
        new_v.append(vc)
        x = x + a
        h = L.apply_norm(x, sp["ln2"], cfg.norm)
        x = x + L.mlp_block(sp["mlp"], h, cfg.mlp)
        group = _slice_stack(params["mamba"], start, gs)
        gcache = _slice_stack(cache["mamba"], start, gs)
        x, gcache = lax.scan(mamba_step_body, x, (group, gcache))
        mamba_out.append(gcache)
        start += gs
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    logits = _head(params, x)
    new_cache = {
        "k": jnp.stack(new_k),
        "v": jnp.stack(new_v),
        "mamba": jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *mamba_out),
    }
    return logits, new_cache


def _head(params, x):
    logits = (x @ params["lm_head"]["w"].astype(x.dtype)).astype(jnp.float32)
    b = params["lm_head"].get("b")
    return logits + b if b is not None else logits
