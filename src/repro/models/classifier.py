"""The paper's client models: a small CNN (App. A.1.1) and an MLP.

The output layer is named ``lm_head`` = {'w': (h, C), 'b': (C,)} so the
HiCS-FL server reads the bias update of every model in the framework
through one accessor (`repro.core.hetero.bias_update`).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init
from repro.models.losses import classifier_loss

IMG = 14  # synthetic "image" side for the CNN


def init_mlp_params(key, cfg, input_dim: int = 64) -> dict:
    ks = jax.random.split(key, 3)
    h = cfg.d_model
    return {
        "fc1": {"w": dense_init(ks[0], (input_dim, h)),
                "b": jnp.zeros((h,), jnp.float32)},
        "fc2": {"w": dense_init(ks[1], (h, h)),
                "b": jnp.zeros((h,), jnp.float32)},
        "lm_head": {"w": dense_init(ks[2], (h, cfg.vocab_size)),
                    "b": jnp.zeros((cfg.vocab_size,), jnp.float32)},
    }


def mlp_apply(params, x) -> jnp.ndarray:
    """x: (B, input_dim) -> logits (B, C)."""
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    return h @ params["lm_head"]["w"] + params["lm_head"]["b"]


def init_cnn_params(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    c1, c2 = 16, cfg.d_model          # conv channels
    side = -(-IMG // 2)               # SAME pooling: ceil(IMG/2) twice
    side = -(-side // 2)
    flat = side * side * c2
    return {
        "conv1": {"w": 0.1 * jax.random.normal(ks[0], (5, 5, 1, c1)),
                  "b": jnp.zeros((c1,), jnp.float32)},
        "conv2": {"w": 0.1 * jax.random.normal(ks[1], (5, 5, c1, c2)),
                  "b": jnp.zeros((c2,), jnp.float32)},
        "fc": {"w": dense_init(ks[2], (flat, cfg.d_ff)),
               "b": jnp.zeros((cfg.d_ff,), jnp.float32)},
        "lm_head": {"w": dense_init(ks[3], (cfg.d_ff, cfg.vocab_size)),
                    "b": jnp.zeros((cfg.vocab_size,), jnp.float32)},
    }


def _conv(x, w, b):
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + b)


def _pool(x):
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                             (1, 2, 2, 1), "SAME")


def cnn_apply(params, x) -> jnp.ndarray:
    """x: (B, IMG*IMG) flattened synthetic image -> logits (B, C)."""
    B = x.shape[0]
    img = x.reshape(B, IMG, IMG, 1)
    h = _pool(_conv(img, params["conv1"]["w"], params["conv1"]["b"]))
    h = _pool(_conv(h, params["conv2"]["w"], params["conv2"]["b"]))
    h = h.reshape(B, -1)
    h = jax.nn.relu(h @ params["fc"]["w"] + params["fc"]["b"])
    return h @ params["lm_head"]["w"] + params["lm_head"]["b"]


def mlp_features(params, x) -> jnp.ndarray:
    """Penultimate activations (Moon's contrastive anchor)."""
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])


def cnn_features(params, x) -> jnp.ndarray:
    B = x.shape[0]
    img = x.reshape(B, IMG, IMG, 1)
    h = _pool(_conv(img, params["conv1"]["w"], params["conv1"]["b"]))
    h = _pool(_conv(h, params["conv2"]["w"], params["conv2"]["b"]))
    h = h.reshape(B, -1)
    return jax.nn.relu(h @ params["fc"]["w"] + params["fc"]["b"])


def make_classifier(cfg, input_dim: int = 64):
    """Returns (init_fn(key), apply_fn(params, x), loss_fn(params, batch))."""
    if cfg.name.startswith("paper-cnn"):
        init = lambda key: init_cnn_params(key, cfg)
        apply, features = cnn_apply, cnn_features
    else:
        init = lambda key: init_mlp_params(key, cfg, input_dim)
        apply, features = mlp_apply, mlp_features

    def loss_fn(params, batch):
        logits = apply(params, batch["x"])
        return classifier_loss(logits, batch["y"])

    return init, apply, loss_fn


def make_classifier_with_features(cfg, input_dim: int = 64):
    """(init, apply, features) — features feed Moon's contrastive term."""
    init, apply, _ = make_classifier(cfg, input_dim)
    features = cnn_features if cfg.name.startswith("paper-cnn") \
        else mlp_features
    return init, apply, features
