"""Synthetic datasets (the offline substitute for the paper's FMNIST /
CIFAR10 / Mini-ImageNet / THUC news benchmarks).

Classification: a Gaussian-mixture manifold per class.  Class c has a
random unit prototype μ_c ∈ R^d plus a low-rank within-class subspace;
samples are μ_c + Us + noise.  Classes are separable but not trivially
so (controlled by ``noise``), so models show a genuine accuracy
trajectory over FL rounds — which is what the paper's Table 1/2
analogues measure.

LM streams: per-client token streams whose unigram/topic distribution is
Dirichlet-skewed, so federated LM fine-tuning exhibits the same label
(= next-token) heterogeneity structure the paper studies for
classification.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    num_classes: int = 10
    dim: int = 196               # 14x14 "image" for the paper CNN
    rank: int = 8                # within-class subspace rank
    noise: float = 0.30          # isotropic noise std
    proto_scale: float = 1.5


def make_classification_data(rng: np.random.Generator, spec: SyntheticSpec,
                             num_samples: int
                             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x (S, dim) f32, y (S,) i32, prototypes (C, dim))."""
    C, d = spec.num_classes, spec.dim
    protos = rng.normal(size=(C, d))
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    protos *= spec.proto_scale
    bases = rng.normal(size=(C, d, spec.rank)) / np.sqrt(d)
    y = rng.integers(0, C, size=num_samples)
    coef = rng.normal(size=(num_samples, spec.rank))
    x = protos[y] + np.einsum("sdr,sr->sd", bases[y], coef) \
        + spec.noise * rng.normal(size=(num_samples, d))
    return x.astype(np.float32), y.astype(np.int32), protos.astype(np.float32)


def make_train_test(rng: np.random.Generator, spec: SyntheticSpec,
                    samples_train: int, samples_test: int
                    ) -> Tuple[Dict[str, np.ndarray],
                               Dict[str, np.ndarray], np.ndarray]:
    """Train/test split of the classification task in the dict layout
    the federated stack consumes: ``train = {x, y}``, ``test = {x, y,
    mask}`` (test mask all-ones).  Shared by the one-experiment builder
    (repro.fed.simulation) and the scenario registry
    (repro.scenarios.registry), so both draw the same task from the
    same rng chain."""
    x, y, protos = make_classification_data(
        rng, spec, samples_train + samples_test)
    train = {"x": x[:samples_train], "y": y[:samples_train]}
    test = {"x": x[samples_train:], "y": y[samples_train:],
            "mask": np.ones(samples_test, dtype=np.float32)}
    return train, test, protos


def make_lm_streams(rng: np.random.Generator, vocab: int, seq_len: int,
                    num_clients: int, seqs_per_client: int,
                    alphas: Sequence[float],
                    num_topics: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Per-client token streams with Dirichlet-skewed topic mixtures.

    Returns (tokens (N, seqs, seq_len) i32, topic_mix (N, num_topics)).
    Each topic is a sparse unigram distribution over the vocab; a
    client's next-token distribution is its topic mixture — the LM
    analogue of a label distribution.
    """
    groups = np.array_split(np.arange(num_clients), len(alphas))
    topic_logits = rng.normal(size=(num_topics, vocab)) * 2.0
    topic_p = _softmax(topic_logits, axis=-1)
    mixes = np.zeros((num_clients, num_topics))
    for g, alpha in zip(groups, alphas):
        for k in g:
            mixes[k] = rng.dirichlet(np.full(num_topics, alpha))
    toks = np.zeros((num_clients, seqs_per_client, seq_len), dtype=np.int32)
    for k in range(num_clients):
        p = mixes[k] @ topic_p
        toks[k] = rng.choice(vocab, size=(seqs_per_client, seq_len), p=p)
    return toks, mixes


def client_label_distributions(client_labels: Sequence[np.ndarray],
                               num_classes: int) -> np.ndarray:
    """Empirical per-client label distribution matrix (N, C)."""
    out = np.zeros((len(client_labels), num_classes))
    for i, y in enumerate(client_labels):
        if len(y):
            cnt = np.bincount(y, minlength=num_classes)
            out[i] = cnt / cnt.sum()
    return out


def pad_and_stack(xs: List[np.ndarray], ys: List[np.ndarray]
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack ragged per-client datasets into (N, Smax, d) + mask.

    Padded rows carry label 0 and mask 0; every jit'd client step takes
    the same shapes, so N clients share one compiled executable and the
    whole cohort can be vmapped (repro.fed.simulation).
    """
    n = len(xs)
    smax = max(1, max(len(x) for x in xs))
    d = xs[0].shape[1]
    X = np.zeros((n, smax, d), dtype=np.float32)
    Y = np.zeros((n, smax), dtype=np.int32)
    M = np.zeros((n, smax), dtype=np.float32)
    for i, (x, y) in enumerate(zip(xs, ys)):
        s = len(x)
        X[i, :s], Y[i, :s], M[i, :s] = x, y, 1.0
    return X, Y, M


def _softmax(x, axis=-1):
    x = x - np.max(x, axis=axis, keepdims=True)
    e = np.exp(x)
    return e / np.sum(e, axis=axis, keepdims=True)
