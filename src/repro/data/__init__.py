from repro.data.synthetic import (SyntheticSpec, client_label_distributions,
                                  make_classification_data, make_lm_streams,
                                  make_train_test, pad_and_stack)

__all__ = ["SyntheticSpec", "client_label_distributions",
           "make_classification_data", "make_lm_streams",
           "make_train_test", "pad_and_stack"]
