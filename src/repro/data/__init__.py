from repro.data.synthetic import (SyntheticSpec, client_label_distributions,
                                  make_classification_data, make_lm_streams,
                                  pad_and_stack)

__all__ = ["SyntheticSpec", "client_label_distributions",
           "make_classification_data", "make_lm_streams", "pad_and_stack"]
