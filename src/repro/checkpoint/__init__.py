from repro.checkpoint.npz import (latest_step, load_pytree, restore,
                                  save_pytree)

__all__ = ["latest_step", "load_pytree", "restore", "save_pytree"]
