"""npz pytree checkpointing with step metadata.

Flat key = '/'-joined tree path; dtype and shape round-trip exactly
(bfloat16 is stored as uint16 bits with a ``__bf16__`` marker since
numpy's npz has no native bfloat16).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "__bf16__"


def _flatten(tree) -> Dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out


def save_pytree(path, tree, step: Optional[int] = None) -> Path:
    """Write `tree` to `<path>` (npz).  Returns the written path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays = {}
    meta = {"step": step, "keys": []}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arrays[key + _BF16] = arr.view(np.uint16)
        else:
            arrays[key] = arr
        meta["keys"].append(key)
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    np.savez(path, **arrays)
    return path if path.suffix == ".npz" else path.with_suffix(
        path.suffix + ".npz")


def load_pytree(path) -> Tuple[Dict[str, np.ndarray], Optional[int]]:
    """Read a checkpoint into {flat_key: array} + step."""
    with np.load(Path(path), allow_pickle=False) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        out = {}
        for k in z.files:
            if k == "__meta__":
                continue
            if k.endswith(_BF16):
                out[k[: -len(_BF16)]] = z[k].view(jnp.bfloat16)
            else:
                out[k] = z[k]
    return out, meta.get("step")


def restore(path, like):
    """Load into the structure of `like` (a pytree template)."""
    flat, step = load_pytree(path)
    template = _flatten(like)
    missing = set(template) - set(flat)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    leaves = []
    for path_leaf, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path_leaf)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    tree_def = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(tree_def, leaves), step


def latest_step(ckpt_dir) -> Optional[Path]:
    """Newest `step_<n>.npz` under `ckpt_dir`."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.is_dir():
        return None
    best, best_n = None, -1
    for p in ckpt_dir.glob("step_*.npz"):
        m = re.match(r"step_(\d+)", p.stem)
        if m and int(m.group(1)) > best_n:
            best, best_n = p, int(m.group(1))
    return best
