"""Config system: model configs, input-shape configs, and the registry.

Every assigned architecture gets one ``<arch>.py`` module in this package
that instantiates a :class:`ModelConfig` with the exact published dims and
registers it. ``get_config(name)`` / ``list_archs()`` are the public API,
and every config can produce a ``reduced()`` variant (<=2 layers,
d_model<=512, <=4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

ARCH_KINDS = ("dense", "moe", "ssm", "hybrid", "audio", "vlm", "classifier")


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style SSD mixer config (used by ssm/hybrid archs)."""
    state_dim: int = 64
    expand: int = 2              # d_inner = expand * d_model
    conv_width: int = 4
    head_dim: int = 64           # mamba2 heads: d_inner / head_dim
    chunk: int = 256             # chunked-scan block length


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64           # rwkv6 time-mix head size
    lora_rank_decay: int = 64    # rank of data-dependent decay LoRA
    lora_rank_mix: int = 32      # rank of token-shift mixing LoRA


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2: mamba2 backbone + shared attention block every `period`."""
    attn_period: int = 6         # one shared-attn application per 6 mamba blocks
    num_shared_blocks: int = 2   # zamba2-7b has 2 alternating shared blocks


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 12
    cross_attn: bool = True
    # frontend is a stub: input_specs() provides (B, frames, d_model) embeddings
    max_source_frames: int = 4096


@dataclass(frozen=True)
class VLMConfig:
    # vision frontend is a stub: input_specs() provides patch embeddings
    num_patches: int = 256
    patch_embed_dim: int = 1024  # pre-projector ViT dim (projector is ours)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                    # one of ARCH_KINDS
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attn-free)
    num_kv_heads: int            # GQA kv heads
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # attention flavor
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0      # 0 = full attention; >0 = SWA window
    rope_theta: float = 10000.0
    # mlp flavor: "swiglu" | "geglu" | "gelu"
    mlp: str = "swiglu"
    # normalization: "rmsnorm" | "layernorm"
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # multiply embeddings by sqrt(d_model)
    # HiCS-FL head options (paper technique):
    lm_head_bias: bool = True    # paper's estimator reads Delta b of the head
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # long-context handling for the long_500k shape:
    #   "native"  - O(1)-state decode (ssm/hybrid) or native SWA (mixtral)
    #   "swa"     - enable sliding-window (window below) only for long_500k
    #   "skip"    - pair skipped (no semantic long-context analogue)
    long_context_mode: str = "swa"
    long_context_window: int = 4096
    # provenance
    source: str = ""             # citation bracket from the assignment

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        small_moe = None
        if self.moe is not None:
            small_moe = dataclasses.replace(
                self.moe, num_experts=min(4, self.moe.num_experts),
                top_k=min(2, self.moe.top_k))
        small_ssm = None
        if self.ssm is not None:
            small_ssm = dataclasses.replace(
                self.ssm, state_dim=min(16, self.ssm.state_dim),
                head_dim=32, chunk=32)
        small_rwkv = None
        if self.rwkv is not None:
            small_rwkv = dataclasses.replace(
                self.rwkv, head_dim=32, lora_rank_decay=8, lora_rank_mix=8)
        small_hybrid = None
        if self.hybrid is not None:
            small_hybrid = dataclasses.replace(
                self.hybrid, attn_period=1, num_shared_blocks=1)
        small_encdec = None
        if self.encdec is not None:
            small_encdec = dataclasses.replace(
                self.encdec, encoder_layers=2, max_source_frames=32)
        small_vlm = None
        if self.vlm is not None:
            small_vlm = dataclasses.replace(
                self.vlm, num_patches=8, patch_embed_dim=64)
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, max(1, heads // 2)) if heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=max(kv, 1) if heads else 0,
            head_dim=64 if heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            moe=small_moe, ssm=small_ssm, rwkv=small_rwkv,
            hybrid=small_hybrid, encdec=small_encdec, vlm=small_vlm,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.kind not in ARCH_KINDS:
        raise ValueError(f"unknown arch kind {cfg.kind!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False

_ARCH_MODULES = (
    "qwen2_5_3b", "seamless_m4t_medium", "rwkv6_3b", "pixtral_12b",
    "mixtral_8x22b", "zamba2_7b", "deepseek_coder_33b", "gemma_7b",
    "granite_moe_1b_a400m", "qwen3_8b", "paper_cnn",
)


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
