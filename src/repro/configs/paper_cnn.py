"""The paper's own client models (App. A.1.1), used for the faithful floor.

* ``paper-cnn``: CNN classifier analogous to the FMNIST model — two conv
  layers + maxpool + fully-connected head (we run it on synthetic
  Gaussian-mixture "images").
* ``paper-mlp``: fast MLP classifier used by most FL unit tests and
  benchmarks (same output-layer structure that HiCS-FL reads).

These are `kind="classifier"` configs; d_model doubles as the hidden width
and vocab_size as the number of classes C.
"""
from repro.configs.base import ModelConfig, register

CNN = register(ModelConfig(
    name="paper-cnn",
    kind="classifier",
    num_layers=2,                # conv blocks
    d_model=64,                  # conv channels / hidden width
    num_heads=0,
    num_kv_heads=0,
    d_ff=128,                    # fc hidden dim
    vocab_size=10,               # classes
    mlp="gelu",
    norm="layernorm",
    long_context_mode="skip",
    source="HiCS-FL App. A.1.1 (FMNIST CNN)",
))

MLP = register(ModelConfig(
    name="paper-mlp",
    kind="classifier",
    num_layers=2,
    d_model=128,
    num_heads=0,
    num_kv_heads=0,
    d_ff=128,
    vocab_size=10,
    mlp="gelu",
    norm="layernorm",
    long_context_mode="skip",
    source="HiCS-FL App. A.1.1 (MLP variant)",
))
