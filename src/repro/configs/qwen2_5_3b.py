"""qwen2.5-3b — dense decoder, GQA (kv=2), QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b",
    kind="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    qk_norm=False,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    long_context_mode="swa",     # full-attn arch: long_500k via SWA variant
    source="hf:Qwen/Qwen2.5-0.5B",
))
