"""granite-moe-1b-a400m — MoE 32 experts top-8, GQA kv=8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    kind="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,                    # per-expert ffn dim
    vocab_size=49_155,
    head_dim=64,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8, capacity_factor=1.25),
    long_context_mode="swa",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
