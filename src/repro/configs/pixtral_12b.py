"""pixtral-12b — pixtral-ViT frontend (stub) + mistral-nemo decoder.
[hf:mistralai/Pixtral-12B-2409]

The vision encoder is a STUB per the assignment carve-out: ``input_specs()``
provides precomputed patch embeddings; we implement the multimodal
projector + the 40L language decoder (d_model 5120, 32H GQA kv=8,
head_dim 128 as in mistral-nemo).
"""
from repro.configs.base import ModelConfig, VLMConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    kind="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131_072,
    head_dim=128,                # mistral-nemo style: q proj 5120 -> 4096
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    vlm=VLMConfig(num_patches=256, patch_embed_dim=1024),
    long_context_mode="swa",
    source="hf:mistralai/Pixtral-12B-2409",
))
