"""gemma-7b — dense, GeGLU, head_dim=256, MHA (kv=16). [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-7b",
    kind="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256_000,
    head_dim=256,                # q proj 3072 -> 4096
    mlp="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
    long_context_mode="swa",
    source="arXiv:2403.08295",
))
