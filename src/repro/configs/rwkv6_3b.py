"""rwkv6-3b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, RWKVConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    kind="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,                 # attention-free
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65_536,
    mlp="rwkv_channel_mix",      # rwkv channel-mix (squared relu)
    norm="layernorm",
    rwkv=RWKVConfig(head_dim=64, lora_rank_decay=64, lora_rank_mix=32),
    long_context_mode="native",  # O(1) recurrent state decode
    source="arXiv:2404.05892",
))
