"""zamba2-7b — hybrid Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

81 Mamba2 layers, with a shared (weight-tied) transformer block applied
periodically (two alternating shared blocks in the public model).
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    kind="hybrid",
    num_layers=81,               # mamba2 blocks
    d_model=3584,
    num_heads=32,                # shared attention block heads
    num_kv_heads=32,             # MHA in the shared block (GQA kv=32)
    d_ff=14336,
    vocab_size=32_000,
    head_dim=112,                # 3584 / 32
    mlp="swiglu",
    norm="rmsnorm",
    ssm=SSMConfig(state_dim=64, expand=2, conv_width=4, head_dim=64,
                  chunk=256),
    hybrid=HybridConfig(attn_period=6, num_shared_blocks=2),
    long_context_mode="native",  # mamba decode state is O(1); shared attn
                                 # uses SWA(4096) for long_500k
    source="arXiv:2411.15242",
))
