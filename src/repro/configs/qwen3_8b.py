"""qwen3-8b — dense, qk_norm, GQA kv=8. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b",
    kind="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12288,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    long_context_mode="swa",
    source="hf:Qwen/Qwen3-8B",
))
