from repro.configs.base import (
    ARCH_KINDS,
    EncDecConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SHAPES,
    SSMConfig,
    ShapeConfig,
    VLMConfig,
    get_config,
    list_archs,
    register,
)

__all__ = [
    "ARCH_KINDS", "EncDecConfig", "HybridConfig", "ModelConfig", "MoEConfig",
    "RWKVConfig", "SHAPES", "SSMConfig", "ShapeConfig", "VLMConfig",
    "get_config", "list_archs", "register",
]
