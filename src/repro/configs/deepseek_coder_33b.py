"""deepseek-coder-33b — llama-arch dense, GQA kv=8. [arXiv:2401.14196]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-coder-33b",
    kind="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32_256,
    head_dim=128,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=100_000.0,
    long_context_mode="swa",
    source="arXiv:2401.14196",
))
