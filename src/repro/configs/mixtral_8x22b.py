"""mixtral-8x22b — MoE 8 experts top-2, GQA kv=8, SWA. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    kind="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32_768,
    head_dim=128,
    sliding_window=4096,         # native SWA
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    long_context_mode="native",  # native sliding window bounds the KV cache
    source="arXiv:2401.04088",
))
