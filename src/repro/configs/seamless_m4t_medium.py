"""seamless-m4t-medium — enc-dec multimodal (audio) backbone. [arXiv:2308.11596]

The mel-spectrogram + conformer feature frontend is a STUB per the
assignment carve-out: ``input_specs()`` provides precomputed frame
embeddings of shape (B, frames, d_model). We implement the transformer
encoder (12L) + decoder (12L) with cross-attention over vocab 256,206.
"""
from repro.configs.base import EncDecConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    kind="audio",
    num_layers=12,               # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,             # GQA kv=16 (i.e. MHA)
    d_ff=4096,
    vocab_size=256_206,
    head_dim=64,
    mlp="gelu",
    norm="layernorm",
    encdec=EncDecConfig(encoder_layers=12, cross_attn=True,
                        max_source_frames=4096),
    # enc-dec speech translation: a 524k-token decode has no semantic
    # analogue -> long_500k skipped.
    long_context_mode="skip",
    source="arXiv:2308.11596",
))
