"""One-call federated experiment builder used by benchmarks and examples.

Recreates the paper's experimental structure on synthetic data: a
multi-α Dirichlet cohort over a Gaussian-mixture classification task,
one of the paper's model families (CNN / MLP), a selector, and the
server round loop.  The paper's three FMNIST/CIFAR10/THUC "settings" map
to `alphas` lists (§4.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.configs import get_config
from repro.data import (SyntheticSpec, client_label_distributions,
                        make_train_test, pad_and_stack)
from repro.fed.client import LocalSpec
from repro.fed.partition import multi_alpha_partition
from repro.fed.server import FedConfig, FederatedServer
from repro.models.classifier import make_classifier, make_classifier_with_features


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    arch: str = "paper-cnn"            # paper-cnn | paper-mlp
    num_clients: int = 50
    num_select: int = 5
    rounds: int = 100
    alphas: Sequence[float] = (0.001, 0.002, 0.005, 0.01, 0.5)
    selector: str = "hics"
    selector_kw: Optional[Dict[str, Any]] = None
    local: LocalSpec = dataclasses.field(default_factory=LocalSpec)
    samples_train: int = 10_000
    samples_test: int = 2_000
    data: SyntheticSpec = dataclasses.field(default_factory=SyntheticSpec)
    eval_every: int = 5
    seed: int = 0
    jit_rounds: bool = False       # scan whole rounds (see fed.server)
    telemetry: Sequence[str] = ()  # metric groups (repro.telemetry.GROUPS)


def build(spec: ExperimentSpec):
    """Returns (server, info) ready to .run()."""
    rng = np.random.default_rng(spec.seed)
    cfg = get_config(spec.arch)
    data_spec = dataclasses.replace(spec.data,
                                    num_classes=cfg.vocab_size)
    train, test, protos = make_train_test(
        rng, data_spec, spec.samples_train, spec.samples_test)
    xtr, ytr = train["x"], train["y"]

    parts, client_alpha = multi_alpha_partition(
        rng, ytr, spec.num_clients, spec.alphas)
    xs = [xtr[p] for p in parts]
    ys = [ytr[p] for p in parts]
    X, Y, M = pad_and_stack(xs, ys)
    label_dists = client_label_distributions(ys, data_spec.num_classes)

    input_dim = data_spec.dim
    if spec.local.algo == "moon":
        init, apply, features = make_classifier_with_features(
            cfg, input_dim=input_dim)
    else:
        init, apply, _ = make_classifier(cfg, input_dim=input_dim)
        features = None

    fed_cfg = FedConfig(
        num_clients=spec.num_clients, num_select=spec.num_select,
        rounds=spec.rounds, selector=spec.selector,
        selector_kw=spec.selector_kw, local=spec.local,
        eval_every=spec.eval_every, seed=spec.seed,
        jit_rounds=spec.jit_rounds, telemetry=tuple(spec.telemetry))
    server = FederatedServer(init, apply, fed_cfg, X, Y, M, test=test,
                             features_fn=features)
    info = {"label_dists": label_dists, "client_alpha": client_alpha,
            "client_sizes": M.sum(axis=1), "prototypes": protos}
    return server, info


def run_experiment(spec: ExperimentSpec, progress: bool = False
                   ) -> Dict[str, Any]:
    server, info = build(spec)
    hist = server.run(progress=progress)
    hist["label_dists"] = info["label_dists"].tolist()
    hist["client_alpha"] = info["client_alpha"].tolist()
    return hist


# The paper's concentration-parameter settings (§4.1), FMNIST block.
PAPER_SETTINGS = {
    "setting1": (0.001, 0.002, 0.005, 0.01, 0.5),   # 80% severe + 20% bal
    "setting2": (0.001, 0.002, 0.005, 0.01, 0.2),   # 80% severe + 20% mild
    "setting3": (0.001,),                            # all severe
}
