"""Non-IID data partitioning (paper App. A.10, following [35]).

For each label i, proportions X_i^(1..N) ~ Dir(α) are drawn and client k
receives X_i^(k) N_i / Σ_j X_i^(j) of the label-i samples.  With several
concentration parameters (the paper's multi-α settings), the training
set is split into |α| equal parts and each part is partitioned over its
client group with its own α — producing cohorts in which e.g. 80% of
clients are severely imbalanced while 20% are balanced.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def dirichlet_partition(rng: np.random.Generator, labels: np.ndarray,
                        num_clients: int, alpha: float,
                        min_per_client: int = 2) -> List[np.ndarray]:
    """Indices of `labels` split over clients with per-label Dir(α)."""
    num_classes = int(labels.max()) + 1
    client_idx: List[List[int]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, alpha))
        counts = _largest_remainder(props, len(idx))
        start = 0
        for k, cnt in enumerate(counts):
            client_idx[k].extend(idx[start:start + cnt])
            start += cnt
    out = [np.asarray(client_idx[k], dtype=np.int64)
           for k in range(num_clients)]
    # Top up starved clients by STEALING from the currently-largest
    # client (never below the floor itself), so the result remains a
    # true partition — every index appears exactly once.
    for k in range(num_clients):
        while len(out[k]) < min_per_client:
            sizes = np.array([len(o) for o in out])
            sizes[k] = -1                        # never donate to self
            donor = int(np.argmax(sizes))
            if sizes[donor] <= max(min_per_client, 1):
                break                            # nothing left to steal
            j = int(rng.integers(len(out[donor])))
            out[k] = np.append(out[k], out[donor][j])
            out[donor] = np.delete(out[donor], j)
    for ids in out:
        rng.shuffle(ids)
    return out


def multi_alpha_partition(rng: np.random.Generator, labels: np.ndarray,
                          num_clients: int, alphas: Sequence[float],
                          ) -> Tuple[List[np.ndarray], np.ndarray]:
    """The paper's multi-α scheme.  Returns (per-client indices,
    per-client α used) — client groups are equal splits over `alphas`,
    each group partitioning an equal slice of the data."""
    alphas = list(alphas)
    n_groups = len(alphas)
    perm = rng.permutation(len(labels))
    data_slices = np.array_split(perm, n_groups)
    client_groups = np.array_split(np.arange(num_clients), n_groups)
    out: List[np.ndarray] = [np.empty(0, dtype=np.int64)] * num_clients
    client_alpha = np.zeros(num_clients)
    for alpha, dslice, cgroup in zip(alphas, data_slices, client_groups):
        sub = dirichlet_partition(rng, labels[dslice], len(cgroup), alpha)
        for local_k, k in enumerate(cgroup):
            out[k] = dslice[sub[local_k]]
            client_alpha[k] = alpha
    return out, client_alpha


def _largest_remainder(props: np.ndarray, total: int) -> np.ndarray:
    raw = props * total
    counts = np.floor(raw).astype(np.int64)
    rem = total - counts.sum()
    order = np.argsort(-(raw - counts))
    counts[order[:rem]] += 1
    return counts
