"""Pluggable latency models for the buffered-async server.

The async tick loop (``repro.fed.async_server``) stays ONE jitted
``lax.scan`` with zero re-jits because arrival order is *data*, not
control flow: a latency model is materialized host-side into two plain
int32 delay tables —

    base   : (N,)    per-client base delay in ticks (systematic
                     heterogeneity: slow hardware, bad links)
    jitter : (T, K)  per-dispatch jitter for the K cohort slots of
                     every tick (stochastic network noise)

and a dispatch of client ``i`` in slot ``s`` of tick ``t`` arrives at
``t + clip(base[i] + jitter[t, s], 0, max_lag)``.  The tables are
drawn from ``numpy.random.default_rng(spec.seed)`` — a PRNG stream
fully independent of the JAX key chain the training loop consumes, so
adding/charging a latency model can never perturb selection or local
training (the parity oracle's identity model is all-zeros by
construction).

The zoo (``LatencySpec.kind``):

  identity    — every delay 0: the async loop degenerates to the sync
                round loop (the parity oracle).
  uniform     — iid jitter ~ U{0, .., scale}.
  lognormal   — heavy-tail iid jitter ~ ⌊LogNormal(mu, scale)⌋; the
                classic straggler-tail shape.
  stragglers  — a ``straggler_frac`` cohort of clients (chosen by a
                deterministic Bernoulli on the spec seed) carries a
                constant ``straggler_delay`` base; everyone else is
                fast.  Models systematic device heterogeneity.
  flash_crowd — jitter ``period − 1 − (t mod period)``: every dispatch
                of a period lands on the period's last tick at once —
                the burst-arrival stress test for the ring buffer's
                overflow accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

KINDS = ("identity", "uniform", "lognormal", "stragglers", "flash_crowd")


@dataclasses.dataclass(frozen=True)
class LatencySpec:
    kind: str = "identity"
    base: int = 0                  # constant base delay added to all
    scale: float = 2.0             # uniform high / lognormal sigma
    mu: float = 0.5                # lognormal location
    straggler_frac: float = 0.2
    straggler_delay: int = 8
    period: int = 8                # flash_crowd burst period
    seed: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"latency kind must be one of {KINDS}, "
                             f"got {self.kind!r}")


def delay_tables(spec: LatencySpec, num_clients: int, ticks: int,
                 k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize ``(base (N,), jitter (T, K))`` int32 delay tables.

    Pure host-side numpy from ``spec.seed`` — rerunning with the same
    spec reproduces the same traffic shape bit-for-bit, and the tables
    ride the scan as ordinary inputs (``jitter`` rows as per-tick xs,
    ``base`` as a closed-over constant)."""
    rng = np.random.default_rng(int(spec.seed))
    n, t, k = int(num_clients), int(ticks), int(k)
    base = np.full(n, int(spec.base), np.int32)
    jitter = np.zeros((t, k), np.int32)
    if spec.kind == "identity":
        base = np.zeros(n, np.int32)
    elif spec.kind == "uniform":
        hi = max(0, int(spec.scale))
        jitter = rng.integers(0, hi + 1, (t, k)).astype(np.int32)
    elif spec.kind == "lognormal":
        jitter = np.floor(rng.lognormal(
            float(spec.mu), float(spec.scale), (t, k))).astype(np.int32)
    elif spec.kind == "stragglers":
        slow = rng.random(n) < float(spec.straggler_frac)
        base = base + np.where(slow, int(spec.straggler_delay),
                               0).astype(np.int32)
        jitter = rng.integers(0, 2, (t, k)).astype(np.int32)
    elif spec.kind == "flash_crowd":
        p = max(1, int(spec.period))
        per_tick = (p - 1 - (np.arange(t) % p)).astype(np.int32)
        jitter = np.broadcast_to(per_tick[:, None], (t, k)).copy()
    return base, jitter


def max_delay(spec: LatencySpec, base: np.ndarray, jitter: np.ndarray,
              max_lag: int) -> int:
    """Largest delay any dispatch can see after the ``max_lag`` clip —
    sizes the server's in-flight window (W = max_delay + 1)."""
    raw = int(base.max(initial=0)) + int(jitter.max(initial=0))
    return max(0, min(raw, int(max_lag)))
