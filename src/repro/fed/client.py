"""Client-side LocalUpdate (Algorithm 1 line 3) for every FL-algorithm ×
optimizer combination the paper analyzes:

  algorithms : fedavg | fedprox (Eq. 67) | feddyn (Eq. 74) | moon (Eq. 91)
  optimizers : sgd | sgd-momentum | adam        (App. A.9)

Every client's dataset is padded to a common (Smax, d) with a sample
mask (repro.data.pad_and_stack), so one jit'd ``local_update`` serves
all clients of a cohort — and the whole cohort can be vmapped
(repro.fed.simulation).  Training runs R epochs of mini-batch steps via
``lax.scan`` with a per-epoch reshuffle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adam, apply_updates, sgd, sgd_momentum

ALGOS = ("fedavg", "fedprox", "feddyn", "moon")
OPTIMIZERS = {"sgd": sgd, "momentum": sgd_momentum, "adam": adam}


@dataclasses.dataclass(frozen=True)
class LocalSpec:
    algo: str = "fedavg"
    optimizer: str = "sgd"
    lr: float = 0.001
    epochs: int = 2              # R in the paper
    batch_size: int = 64        # B in the paper
    mu: float = 0.1              # fedprox/feddyn/moon regularization weight
    moon_tau: float = 0.5        # Moon contrastive temperature

    def __post_init__(self):
        if self.algo not in ALGOS:
            raise ValueError(f"algo must be one of {ALGOS}")
        if self.optimizer not in OPTIMIZERS:
            raise ValueError(
                f"optimizer must be one of {tuple(OPTIMIZERS)}")


def _masked_ce(logits, labels, mask):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    # where(), not multiply-by-zero: padded rows may carry arbitrary
    # gathered values (scenarios index layout), and 0·inf would leak
    # NaN into the mean even though the row is masked out
    per = jnp.where(mask > 0, logz - tgt, 0.0) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1.0)


def _tree_sqdist(a, b):
    return sum(jnp.sum(jnp.square(x - y)) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _tree_dot(a, b):
    return sum(jnp.sum(x * y) for x, y in zip(
        jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


def _moon_term(feat, feat_glob, feat_prev, tau, mask):
    """−log( e^{sim(z, z_g)/τ} / (e^{sim(z, z_g)/τ} + e^{sim(z, z_p)/τ}) )"""
    def cos(u, v):
        un = u / (jnp.linalg.norm(u, axis=-1, keepdims=True) + 1e-8)
        vn = v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-8)
        return jnp.sum(un * vn, axis=-1)
    pos = cos(feat, feat_glob) / tau
    neg = cos(feat, feat_prev) / tau
    per = jax.nn.logsumexp(jnp.stack([pos, neg], -1), axis=-1) - pos
    return (per * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_local_update(apply_fn: Callable, spec: LocalSpec,
                      features_fn: Optional[Callable] = None) -> Callable:
    """Build ``local_update(global_params, extra, x, y, mask, rng,
    lr_scale=1.0)``.

    extra: dict with optional per-client persistent state —
      "h"    : FedDyn's gradient-correction pytree (same shape as params)
      "prev" : Moon's previous-round local params
    ``lr_scale`` is a TRACED multiplier on ``spec.lr`` — the server
    passes its decay schedule through it so a new decay value never
    retraces the jitted cohort step.
    Returns (local_params, new_extra, metrics).
    """
    opt = OPTIMIZERS[spec.optimizer](spec.lr)
    if spec.algo == "moon" and features_fn is None:
        raise ValueError("moon requires a features_fn")

    def loss_for_batch(params, global_params, extra, xb, yb, mb):
        loss, _ = _base(params, xb, yb, mb)
        if spec.algo == "fedprox":
            loss = loss + 0.5 * spec.mu * _tree_sqdist(params, global_params)
        elif spec.algo == "feddyn":
            loss = (loss - _tree_dot(extra["h"], params)
                    + 0.5 * spec.mu * _tree_sqdist(params, global_params))
        elif spec.algo == "moon":
            feat = features_fn(params, xb)
            fg = jax.lax.stop_gradient(features_fn(global_params, xb))
            fp = jax.lax.stop_gradient(features_fn(extra["prev"], xb))
            loss = loss + spec.mu * _moon_term(feat, fg, fp, spec.moon_tau,
                                               mb)
        return loss

    def _base(params, xb, yb, mb):
        logits = apply_fn(params, xb)
        loss = _masked_ce(logits, yb, mb)
        acc = jnp.sum((jnp.argmax(logits, -1) == yb) * mb) \
            / jnp.maximum(mb.sum(), 1.0)
        return loss, acc

    def local_update(global_params, extra, x, y, mask, rng,
                     lr_scale=1.0):
        s_max = x.shape[0]
        bs = min(spec.batch_size, s_max)
        nb = max(1, s_max // bs)
        usable = nb * bs

        def epoch(carry, erng):
            params, opt_state = carry
            perm = jax.random.permutation(erng, s_max)[:usable]
            xb = x[perm].reshape(nb, bs, *x.shape[1:])
            yb = y[perm].reshape(nb, bs)
            mb = mask[perm].reshape(nb, bs)

            def step(carry, inp):
                params, opt_state = carry
                xi, yi, mi = inp
                loss, grads = jax.value_and_grad(loss_for_batch)(
                    params, global_params, extra, xi, yi, mi)
                # fully-masked (padding-only) batches must be a no-op
                live = (mi.sum() > 0).astype(jnp.float32)
                grads = jax.tree_util.tree_map(lambda g: g * live, grads)
                updates, opt_state = opt.update(grads, opt_state, params,
                                                lr_scale=lr_scale)
                params = apply_updates(params, updates)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                step, (params, opt_state), (xb, yb, mb))
            return (params, opt_state), losses.mean()

        params0 = jax.tree_util.tree_map(jnp.asarray, global_params)
        opt_state = opt.init(params0)
        erngs = jax.random.split(rng, spec.epochs)
        (params, _), epoch_losses = jax.lax.scan(
            epoch, (params0, opt_state), erngs)

        new_extra = dict(extra)
        if spec.algo == "feddyn":
            # h_k ← h_k − μ (θ_k − θ^t)
            new_extra["h"] = jax.tree_util.tree_map(
                lambda h, p, g: h - spec.mu * (p - g),
                extra["h"], params, global_params)
        if spec.algo == "moon":
            new_extra["prev"] = params
        final_loss, final_acc = _base(params, x, y, mask)
        metrics = {"train_loss": epoch_losses.mean(),
                   "final_loss": final_loss, "final_acc": final_acc}
        return params, new_extra, metrics

    return local_update


def make_eval_fn(apply_fn: Callable) -> Callable:
    """jit'd (params, x, y, mask) -> (loss, acc); for pow-d's loss_all
    polling and for global test evaluation."""
    @jax.jit
    def evaluate(params, x, y, mask):
        logits = apply_fn(params, x)
        loss = _masked_ce(logits, y, mask)
        acc = jnp.sum((jnp.argmax(logits, -1) == y) * mask) \
            / jnp.maximum(mask.sum(), 1.0)
        return loss, acc
    return evaluate


def init_extra(spec: LocalSpec, params) -> Dict[str, Any]:
    """Per-client persistent algorithm state at round 0."""
    extra: Dict[str, Any] = {}
    if spec.algo == "feddyn":
        extra["h"] = jax.tree_util.tree_map(jnp.zeros_like, params)
    if spec.algo == "moon":
        extra["prev"] = params
    return extra
