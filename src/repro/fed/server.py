"""Federated server: the round loop of Algorithm 1 with pluggable client
selection, for any (init, apply[, features]) model triple.

Per round t:
  1. S^t ← selector.select(t)
  2. whatever the selector requires is computed server-side:
       loss_all  — global-model loss on every client's data (pow-d, FedCor
                   ideal setting); one vmapped forward
       full_all  — 1-step gradient from every client (DivFL ideal setting)
  3. LocalUpdate for the selected clients (one vmapped jit'd cohort step)
  4. θ^{t+1} ← (1/K) Σ_{k∈S^t} θ_k^t   (unbiased-sampling aggregation)
  5. Δb^{(k)} extracted from the head for k ∈ S^t; selector.update(...)

History records per-round train loss / selected ids / Δb-derived
entropies and periodic test accuracy — everything the paper's
figures/tables need.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import head_bias_updates_stacked, make_selector
from repro.fed.client import (LocalSpec, init_extra, make_eval_fn,
                              make_local_update)


@dataclasses.dataclass(frozen=True)
class FedConfig:
    num_clients: int = 50
    num_select: int = 5
    rounds: int = 100
    selector: str = "hics"
    selector_kw: Optional[Dict[str, Any]] = None
    local: LocalSpec = dataclasses.field(default_factory=LocalSpec)
    eval_every: int = 5
    seed: int = 0
    lr_decay_every: int = 10     # paper: lr halves every 10 rounds
    lr_decay: float = 0.5


def _tree_stack_gather(stacked, ids):
    return jax.tree_util.tree_map(lambda a: a[ids], stacked)


def _tree_stack_scatter(stacked, ids, values):
    return jax.tree_util.tree_map(
        lambda a, v: a.at[ids].set(v), stacked, values)


def _flatten_params(tree) -> jnp.ndarray:
    return jnp.concatenate([jnp.ravel(x) for x in
                            jax.tree_util.tree_leaves(tree)])


class FederatedServer:
    """Drives T rounds of federated training over padded client data."""

    def __init__(self, init_fn, apply_fn, cfg: FedConfig,
                 client_x: np.ndarray, client_y: np.ndarray,
                 client_mask: np.ndarray,
                 test: Optional[Dict[str, np.ndarray]] = None,
                 features_fn=None):
        assert client_x.shape[0] == cfg.num_clients
        self.cfg = cfg
        self.x = jnp.asarray(client_x)
        self.y = jnp.asarray(client_y)
        self.mask = jnp.asarray(client_mask)
        self.test = test
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.rng, k0 = jax.random.split(self.rng)
        self.params = init_fn(k0)
        self.apply_fn = apply_fn
        # client weights p_k ∝ |B_k|
        sizes = np.asarray(client_mask.sum(axis=1))
        kw = dict(cfg.selector_kw or {})
        self.selector = make_selector(
            cfg.selector, num_clients=cfg.num_clients,
            num_select=cfg.num_select, total_rounds=cfg.rounds,
            weights=sizes, seed=cfg.seed, **kw)
        self._lu = make_local_update(apply_fn, cfg.local, features_fn)
        # lr_scale rides along as a TRACED scalar (in_axes None), so the
        # paper's lr-decay schedule never re-jits the cohort step
        self._lu_vmapped = jax.jit(jax.vmap(
            self._lu, in_axes=(None, 0, 0, 0, 0, 0, None)))
        self._eval = make_eval_fn(apply_fn)
        self._eval_vmapped = jax.jit(jax.vmap(
            lambda p, x, y, m: self._eval(p, x, y, m),
            in_axes=(None, 0, 0, 0)))
        ex0 = init_extra(cfg.local, self.params)
        self._extras = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.num_clients,) + l.shape),
            ex0) if ex0 else {}
        # DivFL ideal setting: one-step gradients from all clients
        if "full_all" in self.selector.requires:
            one_step = dataclasses.replace(cfg.local, epochs=1,
                                           algo="fedavg")
            lu1 = make_local_update(apply_fn, one_step)
            self._grad_all = jax.jit(jax.vmap(
                lambda p, x, y, m, r: _flatten_params(
                    jax.tree_util.tree_map(
                        lambda a, b: a - b, lu1(p, {}, x, y, m, r)[0], p)),
                in_axes=(None, 0, 0, 0, 0)))
        self.history: Dict[str, list] = {
            "round": [], "train_loss": [], "selected": [],
            "test_round": [], "test_loss": [], "test_acc": [],
            "bias_entropy": [], "wall_s": [],
        }

    # ------------------------------------------------------------------
    def run(self, progress: bool = False) -> Dict[str, list]:
        cfg = self.cfg
        for t in range(cfg.rounds):
            t_start = time.perf_counter()
            # paper's lr schedule: decay 0.5 every 10 rounds — passed as
            # a traced array so a new value is just new data, not a
            # retrace of the cohort step
            decay = jnp.float32(cfg.lr_decay ** (t // cfg.lr_decay_every))

            ids = np.asarray(self.selector.select(t))
            self.rng, kr = jax.random.split(self.rng)
            rngs = jax.random.split(kr, len(ids))
            extras = (_tree_stack_gather(self._extras, ids)
                      if self._extras else {})
            new_params, new_extras, metrics = self._lu_vmapped(
                self.params, extras, self.x[ids], self.y[ids],
                self.mask[ids], rngs, decay)
            if self._extras:
                self._extras = _tree_stack_scatter(self._extras, ids,
                                                   new_extras)
            # Δb per participant (before aggregation overwrites params)
            bias_updates = self._bias_updates(new_params)
            # aggregate: θ^{t+1} = (1/K) Σ θ_k
            self.params = jax.tree_util.tree_map(
                lambda stacked: jnp.mean(stacked, axis=0), new_params)

            kw: Dict[str, Any] = {}
            if bias_updates is not None:
                kw["bias_updates"] = np.asarray(bias_updates)
            if "loss_all" in self.selector.requires:
                losses, _ = self._eval_vmapped(self.params, self.x, self.y,
                                               self.mask)
                kw["losses"] = np.asarray(losses)
            if "full_all" in self.selector.requires:
                self.rng, kg = jax.random.split(self.rng)
                g = self._grad_all(self.params, self.x, self.y, self.mask,
                                   jax.random.split(kg, cfg.num_clients))
                kw["full_updates"] = np.asarray(g)
            elif "full_sel" in self.selector.requires:
                flat_global = _flatten_params(self.params)
                sel_updates = jax.vmap(
                    lambda p: _flatten_params(p) - flat_global)(new_params)
                kw["full_updates"] = np.asarray(sel_updates)
            self.selector.update(t, list(ids), **kw)

            self.history["round"].append(t)
            self.history["train_loss"].append(
                float(np.mean(np.asarray(metrics["train_loss"]))))
            self.history["selected"].append(ids.tolist())
            ent = getattr(self.selector, "estimated_entropies", lambda: None)()
            self.history["bias_entropy"].append(
                None if ent is None else ent.tolist())
            self.history["wall_s"].append(time.perf_counter() - t_start)

            if self.test is not None and (t % cfg.eval_every == 0
                                          or t == cfg.rounds - 1):
                tl, ta = self._eval(self.params, self.test["x"],
                                    self.test["y"], self.test["mask"])
                self.history["test_round"].append(t)
                self.history["test_loss"].append(float(tl))
                self.history["test_acc"].append(float(ta))
                if progress:
                    print(f"round {t:4d} loss={self.history['train_loss'][-1]:.4f} "
                          f"test_acc={float(ta):.4f}", flush=True)
        self.history["select_seconds"] = self.selector.select_seconds
        self.history["update_seconds"] = self.selector.update_seconds
        return self.history

    # ------------------------------------------------------------------
    def _bias_updates(self, new_params_stacked) -> Optional[np.ndarray]:
        """Δb (or bias-free ΔW surrogate) per participant — (K, C).

        One stacked-leaf subtraction over the whole cohort; no
        per-client Python loop."""
        return head_bias_updates_stacked(self.params, new_params_stacked)


def rounds_to_accuracy(history: Dict[str, list], target: float
                       ) -> Optional[int]:
    """First round at which test accuracy reached `target` (Table 2)."""
    for r, a in zip(history["test_round"], history["test_acc"]):
        if a >= target:
            return int(r)
    return None
