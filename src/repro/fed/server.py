"""Federated server: the round loop of Algorithm 1 with pluggable client
selection, for any (init, apply[, features]) model triple.

Per round t:
  1. S^t ← select (functional core: ids, state = fn.select(state, t, key))
  2. LocalUpdate for the selected clients (one vmapped jit'd cohort step)
  3. θ^{t+1} ← (1/K) Σ_{k∈S^t} θ_k^t   (unbiased-sampling aggregation)
  4. whatever the selector ``requires`` is computed server-side:
       loss_all  — global-model loss on every client's data (pow-d, FedCor
                   ideal setting); one vmapped forward
       full_all  — 1-step gradient from every client (DivFL ideal setting)
       full_sel  — participants' flattened θ_k − θ^{t+1} (CS, DivFL's
                   practical refresh="selected" setting)
  5. Δb^{(k)} stacked from the head; state = fn.update(state, t, ids, obs)

Two drivers over the same functional selector core:

  * ``run()`` (host loop) — one Python iteration per round; the
    selector shim executes the jitted select/update transitions.
  * ``run(jit_rounds=True)`` — the whole round is ONE jitted
    ``round_step`` (select → vmapped local update → aggregate → stacked
    Δb / full-update observations → selector update) driven through
    ``lax.scan`` in ``eval_every``-sized segments: zero
    device→host→device transfers between ``select`` and ``update``.
    Every requirement class is computable inside the step — including
    DivFL's all-clients gradient poll, whose per-round key rides the
    scan inputs — so all six selectors scan.  Both paths consume the
    same PRNG-key chain, so they produce identical participant sets
    (for DivFL's ideal mode, up to fp tie-breaking in the greedy
    facility-location argmax once gradients converge — see
    tests/test_full_update_selectors.py).

The selector state is an opaque pytree in both drivers, so selector-
side caches — e.g. incremental HiCS's (N, N) distance cache with K-row
staleness (PR 4) — ride the scan carry and the host-loop shim without
any server-side wiring; tests/test_incremental_selection.py pins the
three drivers to identical 50-round participant sets either way.

History records per-round train loss / selected ids / Δb-derived
entropies and periodic test accuracy — everything the paper's
figures/tables need.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SELECTORS, Observations, head_bias_updates_stacked,
                        make_selector)
from repro.core.hetero import head_num_classes
from repro.core.selectors.functional import state_entropies
from repro.fed.client import (LocalSpec, init_extra, make_eval_fn,
                              make_local_update)
from repro.telemetry import (MetricsSpec, TelemetryCtx, client_true_entropy,
                             make_metrics, trace_span)

#: requirements the scanned round loop can satisfy on-device.  All four
#: are computable inside the jitted round step: loss_all is a vmapped
#: forward, full_sel flattens the cohort's params delta, full_all runs
#: the one-step all-clients gradient poll (DivFL's ideal setting) —
#: so every registered selector can ride ``jit_rounds=True``.
_SCANNABLE = frozenset({"bias_sel", "loss_all", "full_sel", "full_all"})


@dataclasses.dataclass(frozen=True)
class FedConfig:
    num_clients: int = 50
    num_select: int = 5
    rounds: int = 100
    selector: str = "hics"
    selector_kw: Optional[Dict[str, Any]] = None
    local: LocalSpec = dataclasses.field(default_factory=LocalSpec)
    eval_every: int = 5
    seed: int = 0
    lr_decay_every: int = 10     # paper: lr halves every 10 rounds
    lr_decay: float = 0.5
    jit_rounds: bool = False     # scan whole rounds instead of host loop
    #: telemetry metric groups to record (see repro.telemetry.GROUPS);
    #: () = off.  Enabled groups ride the jitted round step as an extra
    #: scan output — the training trajectory is bit-identical either way.
    telemetry: tuple = ()


def _tree_stack_gather(stacked, ids):
    return jax.tree_util.tree_map(lambda a: a[ids], stacked)


def _tree_stack_scatter(stacked, ids, values):
    return jax.tree_util.tree_map(
        lambda a, v: a.at[ids].set(v), stacked, values)


def _flatten_params(tree) -> jnp.ndarray:
    return jnp.concatenate([jnp.ravel(x) for x in
                            jax.tree_util.tree_leaves(tree)])


def aggregate_params(new_params, weights=None):
    """θ^{t+1} from the cohort's stacked local params (K, ...).

    ``weights=None`` is the sync drivers' unbiased-sampling mean
    (1/K) Σ θ_k.  With a (K,) ``weights`` vector the normalized
    weighted mean Σ w_k θ_k / Σ w_k is computed as
    ``mean(θ_k · w̃_k)`` with ``w̃ = w·K/Σw`` — the form the async
    server's staleness weighting uses, because when every weight is
    exactly equal (all ages 0 ⇒ w_k = 1.0) ``w̃ ≡ 1.0`` exactly and
    the weighted program is bit-identical to the unweighted mean.
    That identity is the parity oracle's contract: ``jnp.mean`` and
    ``sum/denom`` lower differently under XLA for non-power-of-two K,
    so ONE definition here is shared by the host loop, the scanned
    round step, the sweep engine and the async server."""
    if weights is None:
        return jax.tree_util.tree_map(
            lambda stacked: jnp.mean(stacked, axis=0), new_params)
    w = jnp.asarray(weights, jnp.float32)
    scale = w * (w.shape[0] / jnp.sum(w))
    return jax.tree_util.tree_map(
        lambda stacked: jnp.mean(
            stacked * scale.reshape((stacked.shape[0],)
                                    + (1,) * (stacked.ndim - 1)),
            axis=0), new_params)


def full_sel_updates(params, new_params) -> jnp.ndarray:
    """The ``full_sel`` observation: participants' flattened
    θ_k − θ^{t+1} against the aggregated global params, (K, P).  ONE
    definition shared by the host loop, the scanned round step and the
    sweep engine — three-way participant-set parity depends on these
    drivers computing bit-identical observations."""
    flat_global = _flatten_params(params)
    return jax.vmap(lambda p: _flatten_params(p) - flat_global)(
        new_params)


def make_grad_all(apply_fn, local: LocalSpec):
    """The ``full_all`` observation (DivFL's ideal setting): a vmapped
    one-step fedavg gradient poll over all clients,
    ``(params, x, y, mask, rngs) -> (N, P)`` flattened θ_k − θ.
    Shared by the server and the sweep engine (see
    :func:`full_sel_updates` on why)."""
    one_step = dataclasses.replace(local, epochs=1, algo="fedavg")
    lu1 = make_local_update(apply_fn, one_step)
    return jax.vmap(
        lambda p, x, y, m, r: _flatten_params(
            jax.tree_util.tree_map(
                lambda a, b: a - b, lu1(p, {}, x, y, m, r)[0], p)),
        in_axes=(None, 0, 0, 0, 0))


class FederatedServer:
    """Drives T rounds of federated training over padded client data."""

    def __init__(self, init_fn, apply_fn, cfg: FedConfig,
                 client_x: np.ndarray, client_y: np.ndarray,
                 client_mask: np.ndarray,
                 test: Optional[Dict[str, np.ndarray]] = None,
                 features_fn=None):
        assert client_x.shape[0] == cfg.num_clients
        self.cfg = cfg
        self.x = jnp.asarray(client_x)
        self.y = jnp.asarray(client_y)
        self.mask = jnp.asarray(client_mask)
        self.test = test
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.rng, k0 = jax.random.split(self.rng)
        self.params = init_fn(k0)
        self.apply_fn = apply_fn
        # client weights p_k ∝ |B_k|
        sizes = np.asarray(client_mask.sum(axis=1))
        kw = dict(cfg.selector_kw or {})
        # size the selector's device buffers up-front so the state
        # pytree never changes shape (scan-carry requirement)
        if cfg.selector not in SELECTORS:
            raise KeyError(f"unknown selector {cfg.selector!r}; known: "
                           f"{sorted(SELECTORS)}")
        requires = SELECTORS[cfg.selector].requires
        if "bias_sel" in requires:
            kw.setdefault("num_classes", head_num_classes(self.params) or 1)
        if requires & {"full_all", "full_sel"}:
            kw.setdefault("feat_dim", sum(
                x.size for x in jax.tree_util.tree_leaves(self.params)))
        self.selector = make_selector(
            cfg.selector, num_clients=cfg.num_clients,
            num_select=cfg.num_select, total_rounds=cfg.rounds,
            weights=sizes, seed=cfg.seed, **kw)
        self._lu = make_local_update(apply_fn, cfg.local, features_fn)
        # lr_scale rides along as a TRACED scalar (in_axes None), so the
        # paper's lr-decay schedule never re-jits the cohort step
        self._lu_vmapped = jax.jit(jax.vmap(
            self._lu, in_axes=(None, 0, 0, 0, 0, 0, None)))
        self._eval = make_eval_fn(apply_fn)
        self._eval_vmapped = jax.jit(jax.vmap(
            lambda p, x, y, m: self._eval(p, x, y, m),
            in_axes=(None, 0, 0, 0)))
        ex0 = init_extra(cfg.local, self.params)
        self._extras = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.num_clients,) + l.shape),
            ex0) if ex0 else {}
        # DivFL ideal setting: one-step gradients from all clients
        if "full_all" in self.selector.requires:
            self._grad_all = jax.jit(make_grad_all(apply_fn, cfg.local))
        self._round_step: Optional[Callable] = None
        self._scan_jit: Optional[Callable] = None
        # device-resident telemetry (repro.telemetry): compiled once for
        # this experiment's shape; with cfg.telemetry == () every field
        # is zero-width and the step is free
        self._metrics = make_metrics(
            MetricsSpec(tuple(cfg.telemetry)), fn=self.selector.fn,
            num_clients=cfg.num_clients, num_select=cfg.num_select)
        self._telc = self._metrics.init()
        # ground truth for the selection group's Ĥ-error fields: the
        # true label entropy of each client's partition (device const)
        self._true_ent = (
            client_true_entropy(self.y, self.mask,
                                int(np.max(np.asarray(client_y))) + 1)
            if "selection" in cfg.telemetry else None)
        self._tel_step = jax.jit(self._metrics.step)
        self._tel_segments: list = []
        self.telemetry: Dict[str, np.ndarray] = {}
        # history timing semantics:
        #   wall_s        — host loop only: per-round wall time (includes
        #                   the first round's compile).  Empty in scanned
        #                   mode, where rounds never hit the host.
        #   segment_wall_s / segment_rounds — scanned mode only: wall
        #                   time of each eval_every-round scan segment
        #                   and its round count (segment 0 includes the
        #                   compile).
        #   rounds_per_s  — derived throughput over all rounds, set by
        #                   _finish() for both drivers.
        self.history: Dict[str, list] = {
            "round": [], "train_loss": [], "selected": [],
            "test_round": [], "test_loss": [], "test_acc": [],
            "bias_entropy": [], "wall_s": [],
            "segment_wall_s": [], "segment_rounds": [],
        }

    # ------------------------------------------------------------------
    @classmethod
    def from_partition(cls, init_fn, apply_fn, cfg: FedConfig,
                       x, y, partition,
                       test: Optional[Dict[str, np.ndarray]] = None,
                       features_fn=None) -> "FederatedServer":
        """Build a server from a dataset + fixed-capacity partition
        (e.g. a ``repro.scenarios`` device :class:`Partition` with
        ``idx``/``mask`` fields).  Client tensors are materialized by
        gathering rows through the index layout — exactly the arrays
        the vmapped sweep engine gathers on the fly, so a host-loop
        run over this server is the sweep's parity oracle."""
        idx = np.asarray(partition.idx)
        return cls(init_fn, apply_fn, cfg, np.asarray(x)[idx],
                   np.asarray(y)[idx],
                   np.asarray(partition.mask, dtype=np.float32),
                   test=test, features_fn=features_fn)

    # ------------------------------------------------------------------
    def run(self, progress: bool = False,
            jit_rounds: Optional[bool] = None) -> Dict[str, list]:
        if self.cfg.jit_rounds if jit_rounds is None else jit_rounds:
            return self._run_scanned(progress)
        cfg = self.cfg
        for t in range(cfg.rounds):
            t_start = time.perf_counter()
            # one key per round, split between selection and the cohort
            # — the SAME chain the scanned path consumes
            self.rng, kr = jax.random.split(self.rng)
            k_sel, k_loc = jax.random.split(kr)
            ids = np.asarray(self.selector.select(t, key=k_sel))
            rngs = jax.random.split(k_loc, len(ids))
            # paper's lr schedule: decay 0.5 every 10 rounds — passed as
            # a traced array so a new value is just new data, not a
            # retrace of the cohort step
            decay = jnp.float32(cfg.lr_decay) ** (t // cfg.lr_decay_every)
            extras = (_tree_stack_gather(self._extras, ids)
                      if self._extras else {})
            new_params, new_extras, metrics = self._lu_vmapped(
                self.params, extras, self.x[ids], self.y[ids],
                self.mask[ids], rngs, decay)
            if self._extras:
                self._extras = _tree_stack_scatter(self._extras, ids,
                                                   new_extras)
            # Δb per participant (before aggregation overwrites params)
            bias_updates = head_bias_updates_stacked(self.params,
                                                     new_params)
            params_before = self.params
            # aggregate: θ^{t+1} = (1/K) Σ θ_k
            self.params = aggregate_params(new_params)

            losses = full_updates = None
            if "loss_all" in self.selector.requires:
                losses, _ = self._eval_vmapped(self.params, self.x, self.y,
                                               self.mask)
            if "full_all" in self.selector.requires:
                self.rng, kg = jax.random.split(self.rng)
                full_updates = self._grad_all(
                    self.params, self.x, self.y, self.mask,
                    jax.random.split(kg, cfg.num_clients))
            elif "full_sel" in self.selector.requires:
                full_updates = full_sel_updates(self.params, new_params)
            self.selector.update(t, list(ids), Observations(
                bias_updates=bias_updates, full_updates=full_updates,
                losses=losses))
            if cfg.telemetry:
                # same compiled metrics step the scanned driver embeds,
                # driven one round at a time
                self._telc, tel = self._tel_step(self._telc, TelemetryCtx(
                    t=jnp.int32(t), ids=jnp.asarray(ids, jnp.int32),
                    state=self.selector.state,
                    train_loss=jnp.mean(metrics["train_loss"]),
                    true_entropy=self._true_ent,
                    params_before=params_before, params_after=self.params,
                    bias_updates=bias_updates, lr_scale=decay))
                self._tel_segments.append(jax.tree_util.tree_map(
                    lambda a: np.asarray(a)[None], tel))

            self.history["round"].append(t)
            self.history["train_loss"].append(
                float(np.mean(np.asarray(metrics["train_loss"]))))
            self.history["selected"].append(ids.tolist())
            ent = self.selector.estimated_entropies()
            self.history["bias_entropy"].append(
                None if ent is None else ent.tolist())
            self.history["wall_s"].append(time.perf_counter() - t_start)

            if self.test is not None and (t % cfg.eval_every == 0
                                          or t == cfg.rounds - 1):
                self._eval_round(t, progress)
        return self._finish()

    # ------------------------------------------------------------------
    def _make_round_step(self) -> Callable:
        """One fully-jitted federated round over the functional selector
        core: (params, extras, selector state, telemetry) carry,
        (t, key[, grad key]) input.  Mirrors the host loop op-for-op —
        including the post-aggregation full-update observations the
        CS/DivFL selectors consume — so both drivers produce identical
        participant sets from the same key chain.  The telemetry step
        only READS round values, so with groups disabled its zero-width
        outputs are dead code XLA removes."""
        cfg = self.cfg
        fn = self.selector.fn
        has_extras = bool(self._extras)
        need_losses = "loss_all" in fn.requires
        need_full_sel = "full_sel" in fn.requires
        need_full_all = "full_all" in fn.requires
        lu_v = jax.vmap(self._lu, in_axes=(None, 0, 0, 0, 0, 0, None))
        tel_step, true_ent = self._metrics.step, self._true_ent

        def round_step(carry, xs):
            params, extras, sstate, telc = carry
            if need_full_all:
                t, kr, kg = xs
            else:
                t, kr = xs
            k_sel, k_loc = jax.random.split(kr)
            ids, sstate = fn.select(sstate, t, k_sel)
            rngs = jax.random.split(k_loc, cfg.num_select)
            decay = jnp.float32(cfg.lr_decay) ** (t // cfg.lr_decay_every)
            ex_sel = (_tree_stack_gather(extras, ids) if has_extras
                      else {})
            params_before = params
            new_params, new_extras, metrics = lu_v(
                params, ex_sel, self.x[ids], self.y[ids], self.mask[ids],
                rngs, decay)
            if has_extras:
                extras = _tree_stack_scatter(extras, ids, new_extras)
            bias_updates = head_bias_updates_stacked(params, new_params)
            params = aggregate_params(new_params)
            losses = full_updates = None
            if need_losses:
                losses, _ = self._eval_vmapped(params, self.x, self.y,
                                               self.mask)
            if need_full_all:
                full_updates = self._grad_all(
                    params, self.x, self.y, self.mask,
                    jax.random.split(kg, cfg.num_clients))
            elif need_full_sel:
                full_updates = full_sel_updates(params, new_params)
            sstate = fn.update(sstate, t, ids, Observations(
                bias_updates=bias_updates, full_updates=full_updates,
                losses=losses))
            train_loss = jnp.mean(metrics["train_loss"])
            telc, tel = tel_step(telc, TelemetryCtx(
                t=t, ids=ids, state=sstate, train_loss=train_loss,
                true_entropy=true_ent, params_before=params_before,
                params_after=params, bias_updates=bias_updates,
                lr_scale=decay))
            ent = state_entropies(fn, sstate)
            out = (ids, train_loss, ent, tel)
            return (params, extras, sstate, telc), out

        return round_step

    def _run_scanned(self, progress: bool = False) -> Dict[str, list]:
        cfg = self.cfg
        fn = self.selector.fn
        unmet = fn.requires - _SCANNABLE
        if unmet or not fn.jit_capable:
            raise ValueError(
                f"jit_rounds=True unsupported for selector {fn.name!r} "
                f"(needs host-side {sorted(unmet)})")
        if self._round_step is None:
            self._round_step = self._make_round_step()
        if self._scan_jit is None:
            self._scan_jit = jax.jit(
                lambda carry, xs: jax.lax.scan(self._round_step, carry, xs))
        carry = (self.params, self._extras, self.selector.state,
                 self._telc)
        # segments of eval_every rounds; evaluation lands after each
        # segment's LAST round (the host loop evals after rounds
        # 0, ee, 2ee, ... — same cadence, one round offset).  Equal
        # segment lengths keep the scanned round_step at one compile.
        seg_len = cfg.eval_every if self.test is not None else cfg.rounds
        need_gk = "full_all" in fn.requires
        t = 0
        while t < cfg.rounds:
            n = min(seg_len, cfg.rounds - t)
            keys, gkeys = [], []
            for _ in range(n):       # same key chain as the host loop:
                self.rng, kr = jax.random.split(self.rng)
                keys.append(kr)
                if need_gk:          # ... kr then the grad-poll key
                    self.rng, kg = jax.random.split(self.rng)
                    gkeys.append(kg)
            ts = jnp.arange(t, t + n, dtype=jnp.int32)
            xs = ((ts, jnp.stack(keys), jnp.stack(gkeys)) if need_gk
                  else (ts, jnp.stack(keys)))
            t_start = time.perf_counter()
            with trace_span(f"fed/scan_segment[{n}]"):
                carry, (ids_seg, loss_seg, ent_seg, tel_seg) = \
                    self._scan_jit(carry, xs)
                jax.block_until_ready(carry)
            # per-SEGMENT wall time: rounds never surface to the host
            # here, so a per-round number would be fiction (the old
            # code wrote the segment mean into every round's wall_s)
            self.history["segment_wall_s"].append(
                time.perf_counter() - t_start)
            self.history["segment_rounds"].append(n)
            ids_np = np.asarray(ids_seg)
            loss_np = np.asarray(loss_seg)
            ent_np = np.asarray(ent_seg)
            for i in range(n):
                self.history["round"].append(t + i)
                self.history["train_loss"].append(float(loss_np[i]))
                self.history["selected"].append(ids_np[i].tolist())
                self.history["bias_entropy"].append(
                    ent_np[i].tolist() if ent_np.shape[-1] else None)
            self._tel_segments.append(jax.tree_util.tree_map(
                np.asarray, tel_seg))
            t += n
            (self.params, self._extras, self.selector.state,
             self._telc) = carry
            if self.test is not None:
                self._eval_round(t - 1, progress)
        return self._finish()

    # ------------------------------------------------------------------
    def _eval_round(self, t: int, progress: bool) -> None:
        tl, ta = self._eval(self.params, self.test["x"],
                            self.test["y"], self.test["mask"])
        self.history["test_round"].append(t)
        self.history["test_loss"].append(float(tl))
        self.history["test_acc"].append(float(ta))
        if progress:
            print(f"round {t:4d} loss={self.history['train_loss'][-1]:.4f} "
                  f"test_acc={float(ta):.4f}", flush=True)

    def _finish(self) -> Dict[str, list]:
        self.history["select_seconds"] = self.selector.select_seconds
        self.history["update_seconds"] = self.selector.update_seconds
        # throughput over every timed round, whichever driver ran
        wall = (sum(self.history["segment_wall_s"])
                or sum(self.history["wall_s"]))
        rounds = (sum(self.history["segment_rounds"])
                  or len(self.history["wall_s"]))
        self.history["rounds_per_s"] = rounds / wall if wall else None
        if self._tel_segments:
            self.telemetry = {
                k: np.concatenate([seg[k] for seg in self._tel_segments])
                for k in self._tel_segments[0]}
        return self.history

def rounds_to_accuracy(history: Dict[str, list], target: float
                       ) -> Optional[int]:
    """First round at which test accuracy reached `target` (Table 2)."""
    for r, a in zip(history["test_round"], history["test_acc"]):
        if a >= target:
            return int(r)
    return None
