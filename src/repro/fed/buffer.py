"""Device-resident fixed-capacity ring buffer for buffered-async FL.

The async server's aggregation queue: arrived client contributions
(local params pytree + Δb row, tagged with client id and dispatch
version) wait here until the fill threshold fires.  The buffer is a
plain :class:`RingBuffer` pytree — every leaf a fixed-shape device
array — so it rides a ``lax.scan`` carry untouched, and all three
operations (init / push / pop) are pure shape-static functions.

Invariants (asserted by tests/test_async_server.py):

  * capacity B is static; ``fill`` ∈ [0, B]; the oldest entry lives at
    ``head``, entry ``i``-th-oldest at ``(head + i) mod B``.
  * ``push`` accepts masked candidate rows IN ROW ORDER (the caller
    orders them oldest-dispatch-first), appends until full, and counts
    the overflow it drops — arrivals are never silently lost, they are
    *accounted* lost (``BENCH_async.json`` reports the drop rate).
  * ``pop(m)`` removes exactly the ``m`` oldest entries (FIFO), so
    staleness-weighted aggregation consumes contributions in arrival
    order and a contribution's age is bounded by its queue time.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class RingBuffer(NamedTuple):
    """Fixed-capacity FIFO of client contributions, as a pytree.

    payload : pytree with (B, ...) leaves — the buffered data (local
              params + Δb row for the async server; opaque here).
    ids     : (B,) int32 — contributing client per slot.
    version : (B,) int32 — server version at the entry's dispatch.
    head    : ()  int32 — slot of the oldest entry.
    fill    : ()  int32 — live entries.
    """
    payload: Any
    ids: jnp.ndarray
    version: jnp.ndarray
    head: jnp.ndarray
    fill: jnp.ndarray


def buffer_init(capacity: int, payload_proto: Any) -> RingBuffer:
    """An empty buffer whose payload leaves are ``(B,) + proto.shape``
    zeros — ``payload_proto`` is ONE entry's pytree (e.g. a params
    pytree plus a (C,) Δb row)."""
    b = int(capacity)
    if b < 1:
        raise ValueError(f"ring buffer capacity must be >= 1, got {b}")
    payload = jax.tree_util.tree_map(
        lambda l: jnp.zeros((b,) + jnp.shape(l), jnp.asarray(l).dtype),
        payload_proto)
    return RingBuffer(payload=payload,
                      ids=jnp.zeros(b, jnp.int32),
                      version=jnp.zeros(b, jnp.int32),
                      head=jnp.int32(0),
                      fill=jnp.int32(0))


def buffer_push(buf: RingBuffer, mask: jnp.ndarray, payload_rows: Any,
                ids: jnp.ndarray, version: jnp.ndarray
                ) -> Tuple[RingBuffer, jnp.ndarray, jnp.ndarray]:
    """Append the masked candidate rows in row order; drop overflow.

    mask         : (R,) bool — which candidate rows arrived this tick.
    payload_rows : pytree with (R, ...) leaves, row-aligned with mask.
    ids, version : (R,) int32.

    Returns ``(buffer, accepted, dropped)`` — accepted + dropped =
    mask.sum().  Rows are appended oldest-row-first, so the caller's
    row ordering IS the FIFO ordering.  Overflow rows (buffer already
    full) are dropped via out-of-range scatter indices with
    ``mode="drop"`` — shape-static, no host branching."""
    b = buf.ids.shape[0]
    mask = mask.astype(bool)
    seq = jnp.cumsum(mask.astype(jnp.int32)) - 1   # rank among arrivals
    free = b - buf.fill
    accept = mask & (seq < free)
    # out-of-range sentinel (b) for rejected rows → dropped by scatter
    slot = jnp.where(accept, (buf.head + buf.fill + seq) % b, b)
    payload = jax.tree_util.tree_map(
        lambda dst, src: dst.at[slot].set(src, mode="drop"),
        buf.payload, payload_rows)
    accepted = jnp.sum(accept.astype(jnp.int32))
    dropped = jnp.sum(mask.astype(jnp.int32)) - accepted
    buf = buf._replace(
        payload=payload,
        ids=buf.ids.at[slot].set(ids.astype(jnp.int32), mode="drop"),
        version=buf.version.at[slot].set(version.astype(jnp.int32),
                                         mode="drop"),
        fill=buf.fill + accepted)
    return buf, accepted, dropped


def buffer_pop(buf: RingBuffer, m: int
               ) -> Tuple[Any, jnp.ndarray, jnp.ndarray, RingBuffer]:
    """Remove and return the ``m`` (static) oldest entries.

    Returns ``(payload, ids, version, buffer)`` with payload leaves
    ``(m, ...)`` in FIFO order.  The caller must guarantee
    ``fill >= m`` (the async server's fire condition does)."""
    m = int(m)
    idx = (buf.head + jnp.arange(m, dtype=jnp.int32)) % buf.ids.shape[0]
    payload = jax.tree_util.tree_map(lambda l: l[idx], buf.payload)
    out_ids, out_ver = buf.ids[idx], buf.version[idx]
    buf = buf._replace(head=(buf.head + m) % buf.ids.shape[0],
                       fill=buf.fill - m)
    return payload, out_ids, out_ver, buf
