"""Federated-learning runtime: partitioning, clients, sync + buffered-
async servers, simulation."""
from repro.fed.async_server import (AsyncConfig, AsyncFederatedServer,
                                    ticks_to_loss)
from repro.fed.buffer import (RingBuffer, buffer_init, buffer_pop,
                              buffer_push)
from repro.fed.client import (ALGOS, OPTIMIZERS, LocalSpec, init_extra,
                              make_eval_fn, make_local_update)
from repro.fed.latency import LatencySpec, delay_tables
from repro.fed.partition import dirichlet_partition, multi_alpha_partition
from repro.fed.server import FedConfig, FederatedServer, rounds_to_accuracy
from repro.fed.simulation import (PAPER_SETTINGS, ExperimentSpec, build,
                                  run_experiment)

__all__ = [
    "AsyncConfig", "AsyncFederatedServer", "ticks_to_loss",
    "RingBuffer", "buffer_init", "buffer_pop", "buffer_push",
    "ALGOS", "OPTIMIZERS", "LocalSpec", "init_extra", "make_eval_fn",
    "make_local_update", "LatencySpec", "delay_tables",
    "dirichlet_partition", "multi_alpha_partition",
    "FedConfig", "FederatedServer", "rounds_to_accuracy",
    "PAPER_SETTINGS", "ExperimentSpec", "build", "run_experiment",
]
