"""Federated-learning runtime: partitioning, clients, server, simulation."""
from repro.fed.client import (ALGOS, OPTIMIZERS, LocalSpec, init_extra,
                              make_eval_fn, make_local_update)
from repro.fed.partition import dirichlet_partition, multi_alpha_partition
from repro.fed.server import FedConfig, FederatedServer, rounds_to_accuracy
from repro.fed.simulation import (PAPER_SETTINGS, ExperimentSpec, build,
                                  run_experiment)

__all__ = [
    "ALGOS", "OPTIMIZERS", "LocalSpec", "init_extra", "make_eval_fn",
    "make_local_update", "dirichlet_partition", "multi_alpha_partition",
    "FedConfig", "FederatedServer", "rounds_to_accuracy",
    "PAPER_SETTINGS", "ExperimentSpec", "build", "run_experiment",
]
