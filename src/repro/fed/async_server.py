"""Buffered-async federated server: FedBuff-style aggregation with
staleness-aware selection, as ONE jitted ``lax.scan`` over ticks.

Sync FL (``repro.fed.server``) blocks each round on all K participants.
Production fleets don't: contributions trickle in behind stragglers,
bursts and dropouts.  This subsystem models that traffic shape without
giving up the repo's everything-on-device discipline:

Per tick t (one scan step, zero host transfers):

  1. DISPATCH — ``select`` a cohort of K clients (same functional
     ``(init, select, update)`` protocol as the sync drivers), run
     their local updates against the CURRENT global params, and stamp
     each contribution with the server ``version``.  The contribution
     (local params pytree + Δb row) enters the in-flight pool with an
     arrival tick ``t + delay`` drawn from the latency model's
     precomputed delay tables (``repro.fed.latency``) — arrival order
     is data, so the scan never re-jits.
  2. ARRIVALS — pool entries whose arrival tick is t are pushed,
     oldest-dispatch-first, into the fixed-capacity ring buffer
     (``repro.fed.buffer``).  Overflow is dropped AND counted.
  3. AGGREGATE — when ``fill >= threshold`` fires, the M oldest
     entries pop (FIFO) and fold into the global params by
     staleness-weighted averaging: ``age = version_now − version_at_
     dispatch``, weight ``w = 1/(1+age)^beta`` (FedBuff/FedAsync;
     ``beta=0`` recovers the plain mean, ``server_mix`` optionally
     anchors to the previous global params).  The selector's
     ``update`` then consumes the popped cohort — duplicate client
     ids across buffered cohorts are resolved NEWEST-WINS before the
     scatter so the write is deterministic — and the staled-id ring
     (``stale_slots`` cohorts wide, see ``core/selectors/functional``)
     records up to M rows for the next ``select``'s cache refresh.

Parity oracle (tests/test_async_server.py): with the identity latency
model, ``capacity = threshold = K``, every tick fires with all ages 0,
so weights are exactly 1.0 and ``aggregate_params`` reduces
bit-identically to the sync mean — the async scan reproduces the sync
scanned loop's participant sets, key chain and parameters BIT-EXACTLY.
That is why aggregation routes through the one shared
:func:`repro.fed.server.aggregate_params` definition.

Age is counted dispatch→application (not dispatch→arrival): a
contribution keeps aging while queued, which is the bound the buffer's
FIFO pop keeps tight.

``full_all`` selectors (DivFL's ideal all-clients gradient poll) are
rejected: an every-tick N-client poll has no async semantics — the
poll would itself be stale.  ``bias_sel`` / ``full_sel`` / ``loss_all``
all ride the tick loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SELECTORS, Observations, head_bias_updates_stacked,
                        make_functional)
from repro.core.hetero import head_num_classes
from repro.core.selectors.functional import state_entropies
from repro.fed.buffer import buffer_init, buffer_pop, buffer_push
from repro.fed.client import (LocalSpec, init_extra, make_eval_fn,
                              make_local_update)
from repro.fed.latency import LatencySpec, delay_tables, max_delay
from repro.fed.server import (_tree_stack_gather, _tree_stack_scatter,
                              aggregate_params, full_sel_updates)
from repro.telemetry import (MetricsSpec, TelemetryCtx, client_true_entropy,
                             make_metrics, trace_span)

#: requirement classes the async tick loop can satisfy on-device.
_ASYNC_SCANNABLE = frozenset({"bias_sel", "loss_all", "full_sel"})


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    num_clients: int = 50
    num_select: int = 5          # cohort size dispatched per tick
    ticks: int = 100             # scan length (≈ sync "rounds")
    selector: str = "hics"
    selector_kw: Optional[Dict[str, Any]] = None
    local: LocalSpec = dataclasses.field(default_factory=LocalSpec)
    capacity: int = 0            # ring-buffer capacity B (0 → K)
    threshold: int = 0           # aggregation fill threshold M (0 → K)
    beta: float = 0.5            # staleness exponent in 1/(1+age)^beta
    server_mix: float = 0.0      # θ ← (1−mix)·agg + mix·θ_prev
    latency: LatencySpec = dataclasses.field(default_factory=LatencySpec)
    max_lag: int = 16            # delay clip → in-flight window W−1
    eval_every: int = 5
    seed: int = 0
    lr_decay_every: int = 10
    lr_decay: float = 0.5
    #: telemetry metric groups to record (see repro.telemetry.GROUPS);
    #: () = off.  The ``async`` group's buffer/staleness fields are
    #: native here — the tick body hands them to the metrics step.
    telemetry: tuple = ()

    def sizes(self):
        """Resolved (K, B, M) with the 0 → K defaults applied."""
        k = int(self.num_select)
        b = int(self.capacity) or k
        m = int(self.threshold) or k
        if m < 1 or m > b:
            raise ValueError(f"threshold must be in [1, capacity]: "
                             f"M={m}, B={b}")
        return k, b, m


class InFlightPool(NamedTuple):
    """Dispatched-but-not-arrived contributions: one row per tick in a
    W-deep window (W = max delay + 1), K slots per row.  Tick t writes
    row ``t mod W`` — safe because every earlier occupant of that row
    arrived at least one tick ago (delays are clipped to W − 1)."""
    payload: Any              # pytree, leaves (W, K, ...)
    ids: jnp.ndarray          # (W, K) int32
    version: jnp.ndarray      # (W, K) int32
    arrive: jnp.ndarray       # (W, K) int32 — absolute arrival tick
    live: jnp.ndarray         # (W, K) bool


def _pool_init(window: int, k: int, payload_proto: Any) -> InFlightPool:
    payload = jax.tree_util.tree_map(
        lambda l: jnp.zeros((window, k) + jnp.shape(l),
                            jnp.asarray(l).dtype), payload_proto)
    return InFlightPool(
        payload=payload,
        ids=jnp.zeros((window, k), jnp.int32),
        version=jnp.zeros((window, k), jnp.int32),
        arrive=jnp.full((window, k), -1, jnp.int32),
        live=jnp.zeros((window, k), bool))


def make_tick_step(cfg: AsyncConfig, fn, local_update: Callable,
                   eval_fn: Callable, get_batch: Callable,
                   get_all: Callable, base_delay, window: int,
                   select_ids: Optional[Callable] = None,
                   has_extras: bool = False, metrics=None,
                   true_entropy=None):
    """Build the jitted async tick body, shared by the standalone
    :class:`AsyncFederatedServer` and the vmapped async sweep runner.

    get_batch(ids) -> (x (K, S, d), y, mask) for the cohort;
    get_all()      -> (x (N, S, d), y, mask) for loss_all polling;
    select_ids(sstate, t, kr, k_sel) -> (ids, sstate) overrides plain
    ``fn.select`` (the sweep runner plugs availability masking in).
    ``metrics`` is a compiled :class:`repro.telemetry.Metrics`
    (defaults to all-off); its carry rides the tick carry and its
    output dict is the scan's last output.  ``true_entropy`` feeds the
    selection group's Ĥ-error fields.

    Returns ``(tick_step, init_runtime)`` where ``init_runtime(params)
    -> (pool, buffer)`` allocates the carry's runtime structures.
    """
    k, b, m = cfg.sizes()
    w = int(window)
    beta, mix = float(cfg.beta), float(cfg.server_mix)
    need_losses = "loss_all" in fn.requires
    need_full_sel = "full_sel" in fn.requires
    unmet = fn.requires - _ASYNC_SCANNABLE
    if unmet:
        raise ValueError(
            f"async server unsupported for selector {fn.name!r} (needs "
            f"{sorted(unmet)}; an every-tick all-clients poll has no "
            "async semantics)")
    lu_v = jax.vmap(local_update, in_axes=(None, 0, 0, 0, 0, 0, None))
    eval_v = jax.vmap(lambda p, cx, cy, cm: eval_fn(p, cx, cy, cm),
                      in_axes=(None, 0, 0, 0))
    if select_ids is None:
        select_ids = lambda sstate, t, kr, k_sel: fn.select(
            sstate, t, k_sel)
    base_delay = jnp.asarray(base_delay, jnp.int32)
    if metrics is None:
        metrics = make_metrics(MetricsSpec(), fn=fn,
                               num_clients=cfg.num_clients, num_select=k)

    def init_runtime(params):
        c = head_num_classes(params) or 1
        proto = {"params": params,
                 "delta_b": jnp.zeros((c,), jnp.float32)}
        return _pool_init(w, k, proto), buffer_init(b, proto)

    def tick_step(carry, xs):
        params, extras, sstate, pool, buf, version, telc = carry
        params_before = params
        t, kr, jit_row = xs
        k_sel, k_loc = jax.random.split(kr)

        # -- 1. dispatch --------------------------------------------------
        ids, sstate = select_ids(sstate, t, kr, k_sel)
        rngs = jax.random.split(k_loc, k)
        decay = jnp.float32(cfg.lr_decay) ** (t // cfg.lr_decay_every)
        cx, cy, cm = get_batch(ids)
        ex_sel = (_tree_stack_gather(extras, ids) if has_extras else {})
        new_params, new_extras, lu_metrics = lu_v(
            params, ex_sel, cx, cy, cm, rngs, decay)
        if has_extras:
            # client-local algorithm state (feddyn h, moon prev) updates
            # when the CLIENT trains — dispatch time — not at arrival
            extras = _tree_stack_scatter(extras, ids, new_extras)
        db = head_bias_updates_stacked(params, new_params)     # (K, C)
        delay = jnp.clip(base_delay[ids] + jit_row, 0, w - 1)
        row = jnp.mod(t, w)
        entry = {"params": new_params, "delta_b": db}
        pool = pool._replace(
            payload=jax.tree_util.tree_map(
                lambda dst, src: dst.at[row].set(src),
                pool.payload, entry),
            ids=pool.ids.at[row].set(ids.astype(jnp.int32)),
            version=pool.version.at[row].set(
                jnp.full((k,), version, jnp.int32)),
            arrive=pool.arrive.at[row].set((t + delay).astype(jnp.int32)),
            live=pool.live.at[row].set(True))

        # -- 2. arrivals --------------------------------------------------
        # pool rows reordered oldest-dispatch-first so the buffer's FIFO
        # order is dispatch order
        order = jnp.mod(t + 1 + jnp.arange(w, dtype=jnp.int32), w)
        arriving = pool.live & (pool.arrive == t)
        flat = lambda l: l[order].reshape((w * k,) + l.shape[2:])
        buf, accepted, dropped = buffer_push(
            buf, flat(arriving),
            jax.tree_util.tree_map(flat, pool.payload),
            flat(pool.ids), flat(pool.version))
        pool = pool._replace(live=pool.live & ~arriving)

        # -- 3. aggregate -------------------------------------------------
        fire = buf.fill >= m

        def do_agg(args):
            params, sstate, buf, version, _ = args
            popped, pids, pver, buf2 = buffer_pop(buf, m)
            ages = (version - pver).astype(jnp.float32)
            wts = jnp.power(1.0 + ages, -beta)
            agg = aggregate_params(popped["params"], wts)
            if mix > 0.0:
                agg = jax.tree_util.tree_map(
                    lambda a, p: (1.0 - mix) * a + mix * p, agg, params)
            # duplicate client ids across buffered cohorts: resolve
            # NEWEST-WINS so the selector's scatter writes one value
            # per id deterministically (j keeps the row of the last
            # occurrence of its id)
            same = pids[None, :] == pids[:, None]            # (M, M)
            win = jnp.argmax(
                same * (jnp.arange(m, dtype=jnp.int32) + 1)[None, :],
                axis=1)
            losses = full_updates = None
            if need_losses:
                ax, ay, am = get_all()
                losses, _ = eval_v(agg, ax, ay, am)
            if need_full_sel:
                full_updates = full_sel_updates(
                    agg, popped["params"])[win]
            sstate2 = fn.update(sstate, t, pids, Observations(
                bias_updates=popped["delta_b"][win],
                full_updates=full_updates, losses=losses))
            return agg, sstate2, buf2, version + jnp.int32(1), ages

        idle_ages = jnp.full((m,), -1.0, jnp.float32)
        params, sstate, buf, version, agg_ages = jax.lax.cond(
            fire, do_agg, lambda args: args,
            (params, sstate, buf, version, idle_ages))

        # version lag of the oldest still-buffered entry (0 when empty)
        slots = jnp.arange(b, dtype=jnp.int32)
        live_ver = jnp.where(slots < buf.fill,
                             buf.version[jnp.mod(buf.head + slots, b)],
                             jnp.iinfo(jnp.int32).max)
        version_lag = jnp.where(buf.fill > 0,
                                version - jnp.min(live_ver), 0)

        ent = state_entropies(fn, sstate)
        train_loss = jnp.mean(lu_metrics["train_loss"])
        telc, tel = metrics.step(telc, TelemetryCtx(
            t=t, ids=ids, state=sstate, train_loss=train_loss,
            true_entropy=true_entropy, params_before=params_before,
            params_after=params, bias_updates=db, lr_scale=decay,
            fired=fire, fill=buf.fill, accepted=accepted,
            dropped=dropped, version=version, version_lag=version_lag,
            agg_ages=agg_ages))
        out = (ids, train_loss, ent,
               fire, buf.fill, accepted, dropped, version, tel)
        return (params, extras, sstate, pool, buf, version, telc), out

    return tick_step, init_runtime


class AsyncFederatedServer:
    """Drives T async ticks over padded client data — the buffered
    counterpart of :class:`repro.fed.server.FederatedServer`, consuming
    the IDENTICAL PRNG-key chain (one round key per tick, split into
    selection/cohort keys) so the identity-latency configuration is the
    sync scanned loop bit-for-bit."""

    def __init__(self, init_fn, apply_fn, cfg: AsyncConfig,
                 client_x: np.ndarray, client_y: np.ndarray,
                 client_mask: np.ndarray,
                 test: Optional[Dict[str, np.ndarray]] = None,
                 features_fn=None):
        assert client_x.shape[0] == cfg.num_clients
        self.cfg = cfg
        k, b, m = cfg.sizes()
        self.x = jnp.asarray(client_x)
        self.y = jnp.asarray(client_y)
        self.mask = jnp.asarray(client_mask)
        self.test = test
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.rng, k0 = jax.random.split(self.rng)
        self.params = init_fn(k0)
        self.apply_fn = apply_fn

        if cfg.selector not in SELECTORS:
            raise KeyError(f"unknown selector {cfg.selector!r}; known: "
                           f"{sorted(SELECTORS)}")
        kw = dict(cfg.selector_kw or {})
        requires = SELECTORS[cfg.selector].requires
        if "bias_sel" in requires:
            kw.setdefault("num_classes", head_num_classes(self.params) or 1)
        if requires & {"full_all", "full_sel"}:
            kw.setdefault("feat_dim", sum(
                x.size for x in jax.tree_util.tree_leaves(self.params)))
        # the staled-id ring must cover one aggregation's M ids
        kw.setdefault("stale_slots", -(-m // k))
        # weights p_k ∝ |B_k| through the shim's exact normalization
        sizes = np.asarray(client_mask.sum(axis=1), np.float64)
        weights = sizes / sizes.sum()
        self.fn = make_functional(
            cfg.selector, num_clients=cfg.num_clients, num_select=k,
            total_rounds=cfg.ticks, weights=weights, **kw)
        # selector-init key: the OO shim's chain (split of PRNGKey(seed))
        _, k_sel0 = jax.random.split(jax.random.PRNGKey(cfg.seed))
        self.state = self.fn.init(k_sel0)

        self._lu = make_local_update(apply_fn, cfg.local, features_fn)
        self._eval = make_eval_fn(apply_fn)
        ex0 = init_extra(cfg.local, self.params)
        self._extras = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.num_clients,) + l.shape),
            ex0) if ex0 else {}

        base, jitter = delay_tables(cfg.latency, cfg.num_clients,
                                    cfg.ticks, k)
        self._window = max_delay(cfg.latency, base, jitter,
                                 cfg.max_lag) + 1
        self._jitter = jnp.asarray(
            np.clip(jitter, 0, self._window - 1), jnp.int32)
        self._metrics = make_metrics(
            MetricsSpec(tuple(cfg.telemetry)), fn=self.fn,
            num_clients=cfg.num_clients, num_select=k)
        self._telc = self._metrics.init()
        true_ent = (client_true_entropy(
            self.y, self.mask, int(np.max(np.asarray(client_y))) + 1)
            if "selection" in cfg.telemetry else None)
        self._tick_step, init_runtime = make_tick_step(
            cfg, self.fn, self._lu, self._eval,
            get_batch=lambda ids: (self.x[ids], self.y[ids],
                                   self.mask[ids]),
            get_all=lambda: (self.x, self.y, self.mask),
            base_delay=base, window=self._window,
            has_extras=bool(self._extras), metrics=self._metrics,
            true_entropy=true_ent)
        self._pool, self._buffer = init_runtime(self.params)
        self._version = jnp.int32(0)
        self._scan_jit = jax.jit(
            lambda carry, xs: jax.lax.scan(self._tick_step, carry, xs))
        self._tel_segments: list = []
        self.telemetry: Dict[str, np.ndarray] = {}
        # timing: ticks never surface to the host, so only per-SEGMENT
        # wall times exist here (segment 0 includes the compile);
        # ticks_per_s is derived at the end of run().  wall_s stays an
        # empty list for shape-compat with the sync history.
        self.history: Dict[str, list] = {
            "round": [], "train_loss": [], "selected": [],
            "fired": [], "buffer_fill": [], "accepted": [],
            "dropped": [], "version": [], "bias_entropy": [],
            "test_round": [], "test_loss": [], "test_acc": [],
            "wall_s": [], "segment_wall_s": [], "segment_rounds": [],
        }

    # ------------------------------------------------------------------
    def run(self, progress: bool = False) -> Dict[str, list]:
        cfg = self.cfg
        carry = (self.params, self._extras, self.state, self._pool,
                 self._buffer, self._version, self._telc)
        seg_len = cfg.eval_every if self.test is not None else cfg.ticks
        t = 0
        while t < cfg.ticks:
            n = min(seg_len, cfg.ticks - t)
            keys = []
            for _ in range(n):      # the sync server's exact key chain
                self.rng, kr = jax.random.split(self.rng)
                keys.append(kr)
            ts = jnp.arange(t, t + n, dtype=jnp.int32)
            xs = (ts, jnp.stack(keys), self._jitter[t:t + n])
            t_start = time.perf_counter()
            with trace_span(f"fed/async_tick_segment[{n}]"):
                carry, outs = self._scan_jit(carry, xs)
                jax.block_until_ready(carry)
            self.history["segment_wall_s"].append(
                time.perf_counter() - t_start)
            self.history["segment_rounds"].append(n)
            tel_seg = outs[-1]
            (ids_seg, loss_seg, ent_seg, fired_seg, fill_seg, acc_seg,
             drop_seg, ver_seg) = [np.asarray(o) for o in outs[:-1]]
            for i in range(n):
                self.history["round"].append(t + i)
                self.history["train_loss"].append(float(loss_seg[i]))
                self.history["selected"].append(ids_seg[i].tolist())
                self.history["fired"].append(bool(fired_seg[i]))
                self.history["buffer_fill"].append(int(fill_seg[i]))
                self.history["accepted"].append(int(acc_seg[i]))
                self.history["dropped"].append(int(drop_seg[i]))
                self.history["version"].append(int(ver_seg[i]))
                self.history["bias_entropy"].append(
                    ent_seg[i].tolist() if ent_seg.shape[-1] else None)
            self._tel_segments.append(jax.tree_util.tree_map(
                np.asarray, tel_seg))
            t += n
            (self.params, self._extras, self.state, self._pool,
             self._buffer, self._version, self._telc) = carry
            if self.test is not None:
                tl, ta = self._eval(self.params, self.test["x"],
                                    self.test["y"], self.test["mask"])
                self.history["test_round"].append(t - 1)
                self.history["test_loss"].append(float(tl))
                self.history["test_acc"].append(float(ta))
                if progress:
                    print(f"tick {t - 1:4d} "
                          f"loss={self.history['train_loss'][-1]:.4f} "
                          f"test_acc={float(ta):.4f}", flush=True)
        self.history["aggregations"] = int(np.sum(self.history["fired"]))
        self.history["dropped_total"] = int(np.sum(self.history["dropped"]))
        self.history["mean_fill"] = float(np.mean(
            self.history["buffer_fill"]))
        wall = sum(self.history["segment_wall_s"])
        self.history["ticks_per_s"] = (
            sum(self.history["segment_rounds"]) / wall if wall else None)
        if self._tel_segments:
            self.telemetry = {
                k: np.concatenate([seg[k] for seg in self._tel_segments])
                for k in self._tel_segments[0]}
        return self.history


def ticks_to_loss(history: Dict[str, list], target: float
                  ) -> Optional[int]:
    """First tick at which train loss dipped to ``target`` — the
    time-to-target metric ``BENCH_async.json`` compares sync vs async
    under increasing straggler severity."""
    for t, l in zip(history["round"], history["train_loss"]):
        if l <= target:
            return int(t)
    return None
