"""Multi-seed / multi-scenario sweep driver — the batched-evaluation
entrypoint over ``repro.scenarios``.

Cross-products scenarios × selectors, vmaps the seeds of every cell
into one XLA program, and writes:

  * ``--out``   full results: per-seed + mean±std accuracy/entropy
                trajectories per (scenario, selector) cell;
  * ``--bench`` ``BENCH_sweep.json``: vmapped-seeds vs python-seed-loop
                wall time (and optionally the FederatedServer host loop
                via ``--host``), the per-PR throughput trajectory CI
                uploads.

Usage:
  PYTHONPATH=src python -m repro.launch.sweep --quick
  PYTHONPATH=src python -m repro.launch.sweep \\
      --scenarios mixed_80_20 dir_severe shards2 --selectors hics random \\
      --seeds 8 --rounds 40 --out SWEEP.json --bench BENCH_sweep.json
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro.data import SyntheticSpec
from repro.fed import LocalSpec
from repro.scenarios import SCENARIOS, SweepSpec, bench_sweep, run_sweep


def _sanitize(obj):
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", nargs="+",
                    default=["mixed_80_20", "dir_mild"],
                    choices=sorted(SCENARIOS))
    ap.add_argument("--selectors", nargs="+", default=["hics", "random"])
    ap.add_argument("--seeds", type=int, default=4,
                    help="number of seeds (0..n-1)")
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--select", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--samples", type=int, default=1000)
    ap.add_argument("--cap", type=int, default=0,
                    help="per-client capacity (0 → 4·S/N)")
    ap.add_argument("--dim", type=int, default=64,
                    help="synthetic feature dim")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--quick", action="store_true",
                    help="CI preset: 2 seeds × 2 scenarios × 2 selectors"
                         ", 6 rounds")
    ap.add_argument("--host", action="store_true",
                    help="also time the FederatedServer host loop")
    ap.add_argument("--telemetry", default="",
                    help="write per-round telemetry to this JSONL path "
                         "(enables the selection/training/fairness "
                         "metric groups; see docs/observability.md)")
    ap.add_argument("--out", default="")
    ap.add_argument("--bench", default="BENCH_sweep.json")
    args = ap.parse_args()

    groups = ("selection", "training", "fairness") if args.telemetry else ()

    if args.quick:
        spec = SweepSpec(
            scenarios=("mixed_80_20", "dir_mild"),
            selectors=("hics", "random"), seeds=(0, 1),
            num_clients=10, num_select=3, rounds=6,
            samples_train=400, samples_test=120,
            data=SyntheticSpec(dim=16, rank=2, noise=0.5),
            local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                            epochs=1, batch_size=32),
            telemetry=groups)
        bench_spec = SweepSpec(
            scenarios=("mixed_80_20", "dir_mild"),
            selectors=("hics", "random"), seeds=(0, 1, 2, 3),
            num_clients=10, num_select=3, rounds=6,
            samples_train=400, samples_test=120,
            data=SyntheticSpec(dim=16, rank=2, noise=0.5),
            local=LocalSpec(algo="fedavg", optimizer="sgd", lr=0.1,
                            epochs=1, batch_size=32))
    else:
        spec = SweepSpec(
            scenarios=tuple(args.scenarios),
            selectors=tuple(args.selectors),
            seeds=tuple(range(args.seeds)),
            num_clients=args.clients, num_select=args.select,
            rounds=args.rounds, samples_train=args.samples,
            samples_test=max(64, args.samples // 5),
            cap=args.cap or None,
            data=SyntheticSpec(dim=args.dim, noise=0.5),
            local=LocalSpec(algo="fedavg", optimizer="sgd", lr=args.lr,
                            epochs=args.epochs, batch_size=32),
            telemetry=groups)
        bench_spec = spec

    print(f"== sweep: {len(spec.scenarios)} scenarios × "
          f"{len(spec.selectors)} selectors × {len(spec.seeds)} seeds "
          f"(vmapped) ==", flush=True)
    res = run_sweep(spec, progress=True)
    if args.telemetry:
        from repro.telemetry import write_sweep
        cells = {name: cell["telemetry"]
                 for name, cell in res["grid"].items()}
        write_sweep(args.telemetry, cells,
                    meta={"driver": "launch.sweep",
                          "groups": list(groups),
                          "rounds": spec.rounds,
                          "seeds": list(spec.seeds)})
        print(f"wrote telemetry {args.telemetry}", flush=True)
    if args.out:
        Path(args.out).write_text(json.dumps(_sanitize(res), indent=1))
        print(f"wrote {args.out}", flush=True)

    print(f"== bench: vmapped vs serial on {len(bench_spec.seeds)} seeds "
          f"==", flush=True)
    bench = bench_sweep(bench_spec, include_host=args.host or args.quick)
    if args.bench:
        Path(args.bench).write_text(json.dumps(_sanitize(bench), indent=1))
        print(f"wrote {args.bench}", flush=True)


if __name__ == "__main__":
    main()
