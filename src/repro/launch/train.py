"""Federated LM fine-tuning driver — the framework's end-to-end train
entrypoint, combining:

  * an assigned architecture (``--arch``, reduced or full),
  * synthetic per-client token streams with Dirichlet topic skew,
  * per-round client selection (HiCS-FL or any baseline),
  * pjit'd local training on the mesh (CPU: 1x1 host mesh; TPU: the
    16x16 / 2x16x16 production mesh),
  * npz checkpointing.

Federation pattern: each round the server broadcasts θ^t, the selected
clients run R local epochs on their own token stream, the server
averages the returned models and feeds the LM-head updates (Δb or the
bias-free ΔW-row-mean surrogate) to the selector.  Exactly Algorithm 1,
with the classifier replaced by a language model — the regime where
HiCS-FL's O(C) selection actually matters (C = vocab).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --rounds 20 --clients 8 --select 2 --selector hics
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import get_config
from repro.core import (head_bias_updates_stacked, head_num_classes,
                        make_selector)
from repro.data import make_lm_streams
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.optim import adam, apply_updates, clip_by_global_norm, sgd


def local_lm_update(api, params, tokens, lr, epochs, opt_name="sgd"):
    """R epochs of LM training on one client's (num_seqs, S) stream."""
    opt = (adam(lr) if opt_name == "adam" else sgd(lr))

    @jax.jit
    def run(params, tokens):
        opt_state = opt.init(params)

        def seq_step(carry, seq):
            params, opt_state = carry
            batch = {"tokens": seq[None, :-1],
                     "targets": seq[None, 1:],
                     "loss_mask": jnp.ones((1, seq.shape[0] - 1),
                                           jnp.float32)}

            def lf(p):
                loss, m = api.loss(p, batch, dtype=jnp.float32)
                return loss

            loss, grads = jax.value_and_grad(lf)(params)
            grads, _ = clip_by_global_norm(grads, 1.0)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), loss

        def epoch(carry, _):
            carry, losses = jax.lax.scan(seq_step, carry, tokens)
            return carry, losses.mean()

        (params, _), losses = jax.lax.scan(
            epoch, (params, opt_state), jnp.arange(epochs))
        return params, losses.mean()

    return run(params, tokens)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--select", type=int, default=2)
    ap.add_argument("--selector", default="hics")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--seqs-per-client", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--temperature", type=float, default=0.01)
    ap.add_argument("--alphas", type=float, nargs="+",
                    default=[0.05, 0.05, 0.05, 5.0])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--telemetry", default="",
                    help="write per-round telemetry to this JSONL path "
                         "(training/selection/fairness fields; see "
                         "docs/observability.md)")
    ap.add_argument("--out", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    rng = np.random.default_rng(args.seed)
    toks, mixes = make_lm_streams(
        rng, cfg.vocab_size, args.seq_len + 1, args.clients,
        args.seqs_per_client, args.alphas)
    toks = jnp.asarray(toks)

    key = jax.random.PRNGKey(args.seed)
    params = api.init(key)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M vocab={cfg.vocab_size}")

    # uniform kwarg surface: selectors that don't use a kwarg ignore it,
    # so there is no per-selector construction branch
    sel = make_selector(args.selector, num_clients=args.clients,
                        num_select=args.select, total_rounds=args.rounds,
                        temperature=args.temperature,
                        num_classes=head_num_classes(params) or 1,
                        seed=args.seed)

    mesh = make_host_mesh()
    history = {"round": [], "loss": [], "selected": [],
               "bias_entropy": [], "wall_s": []}
    with mesh:
        for t in range(args.rounds):
            t0 = time.time()
            ids = sel.select(t)
            new_params, losses = [], []
            for k in ids:
                pk, loss = local_lm_update(api, params, toks[k], args.lr,
                                           args.epochs)
                new_params.append(pk)
                losses.append(float(loss))
            # Δb for the whole cohort in one stacked-leaf subtraction
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_params)
            dbs = head_bias_updates_stacked(params, stacked)
            params = jax.tree_util.tree_map(
                lambda s: jnp.mean(s, axis=0), stacked)
            sel.update(t, ids, bias_updates=dbs)
            ent = sel.estimated_entropies()
            history["round"].append(t)
            history["loss"].append(float(np.mean(losses)))
            history["selected"].append(list(map(int, ids)))
            history["bias_entropy"].append(
                None if ent is None else ent.tolist())
            history["wall_s"].append(time.time() - t0)
            print(f"round {t:3d} loss={np.mean(losses):.4f} "
                  f"sel={list(ids)} "
                  f"({history['wall_s'][-1]:.1f}s)", flush=True)
            if args.ckpt_dir and (t + 1) % 10 == 0:
                save_pytree(Path(args.ckpt_dir) / f"step_{t+1}.npz",
                            params, step=t + 1)
    history["select_seconds"] = sel.select_seconds
    history["update_seconds"] = sel.update_seconds
    if args.telemetry:
        from repro.telemetry import write_run
        # same field names as the in-scan metric groups
        # (repro.telemetry.metrics) so downstream tooling reads both
        counts = np.zeros(args.clients)
        part, eff = [], []
        for ids in history["selected"]:
            counts[ids] += 1
            p = counts / counts.sum()
            h = -(p * np.log(np.where(p > 0, p, 1.0))).sum()
            part.append((counts > 0).mean())
            eff.append(np.exp(h) / args.clients)
        tel = {"training/loss": np.asarray(history["loss"], np.float32),
               "fairness/participation": np.asarray(part, np.float32),
               "fairness/eff_participation": np.asarray(eff, np.float32)}
        ents = history["bias_entropy"]
        if any(e is not None for e in ents):
            tel["selection/ent_mean"] = np.asarray(
                [np.nan if e is None else float(np.mean(e)) for e in ents],
                np.float32)
        write_run(args.telemetry, tel,
                  meta={"driver": "launch.train", "arch": cfg.name,
                        "selector": args.selector, "rounds": args.rounds,
                        "clients": args.clients})
        print(f"wrote telemetry {args.telemetry}", flush=True)
    if args.out:
        Path(args.out).write_text(json.dumps(history, indent=1))
    print("done. final loss:", history["loss"][-1])


if __name__ == "__main__":
    main()
