"""Step builders shared by the training driver, the serving driver and the
multi-pod dry-run: train_step (fwd + bwd + optimizer), prefill_step and
serve_step (one-token decode + greedy sample).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim import apply_updates, clip_by_global_norm


def make_train_step(api, optimizer, *, dtype=jnp.bfloat16,
                    clip_norm: float = 1.0,
                    cast_params_bf16: bool = False):
    """cast_params_bf16: mixed-precision compute copy — f32 master params
    are cast to bf16 ONCE per step before the layer scan, so the FSDP
    all-gathers and the gradient all-reduces move bf16 instead of f32
    (2x wire reduction).  The optimizer still updates the f32
    masters."""
    def train_step(state, batch):
        def lf(p):
            if cast_params_bf16:
                p = jax.tree_util.tree_map(
                    lambda x: x.astype(jnp.bfloat16)
                    if x.dtype == jnp.float32 else x, p)
            return api.loss(p, batch, dtype=dtype)

        (loss, metrics), grads = jax.value_and_grad(
            lf, has_aux=True)(state["params"])
        if cast_params_bf16:
            grads = jax.tree_util.tree_map(
                lambda g, x: g.astype(x.dtype), grads, state["params"])
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt = optimizer.update(grads, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        metrics = dict(metrics, grad_norm=gnorm)
        new_state = {"params": params, "opt": opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_init_state(api, optimizer):
    def init_state(rng):
        params = api.init(rng)
        return {"params": params, "opt": optimizer.init(params),
                "step": jnp.zeros((), jnp.int32)}
    return init_state


def make_prefill_step(api, *, dtype=jnp.bfloat16, cache_extra: int = 0):
    """cache_extra: decode headroom slots appended to the KV cache — set
    to the number of tokens you intend to generate after the prefill."""
    def prefill_step(params, batch):
        logits, cache = api.prefill(params, batch, dtype=dtype,
                                    cache_extra=cache_extra)
        token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return token[:, None], cache
    return prefill_step


def make_serve_step(api, *, long_context: bool = False, dtype=jnp.bfloat16):
    def serve_step(params, cache, batch):
        logits, cache = api.decode_step(params, cache, batch,
                                        long_context=long_context,
                                        dtype=dtype)
        token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return token[:, None], cache
    return serve_step
