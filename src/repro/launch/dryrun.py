import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory/cost/collective artifacts.

MUST be run as a module entrypoint (`python -m repro.launch.dryrun`) — the
two lines above run before any jax import so the 512 placeholder devices
exist when jax initializes. Never import this module from tests.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all [--mesh pod1|pod2] [--skip-existing]
  python -m repro.launch.dryrun --summary

Artifacts: benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models import cache_specs, get_model, input_specs, supports_shape
from repro.optim import adam
from repro.roofline import HW_V5E, model_flops, parse_collectives, \
    roofline_terms
from repro.roofline.hlo_cost import analyze as hlo_analyze
from repro.sharding import (ShardingPolicy, batch_pspecs, cache_pspecs,
                            param_pspecs, to_shardings, use_policy)

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out or None


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def run_combo(arch: str, shape_name: str, mesh_name: str,
              param_dtype=jnp.float32, policy_mode: str = "2d") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.mode, "policy": policy_mode}
    if not supports_shape(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = (f"long_context_mode={cfg.long_context_mode} "
                         "(see configs/base.py)")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    policy = ShardingPolicy(mesh, mode=policy_mode)
    api = get_model(cfg)
    long_context = shape.name == "long_500k"
    batch_sds = input_specs(cfg, shape)

    t0 = time.time()
    with mesh, use_policy(policy):
        if shape.mode == "train":
            opt = adam(1e-4)
            state_sds = jax.eval_shape(
                lambda: {
                    "params": api.init(jax.random.PRNGKey(0)),
                    "opt": opt.init(
                        jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))),
                    "step": jnp.zeros((), jnp.int32),
                })
            state_ps = {
                "params": param_pspecs(state_sds["params"], policy),
                "opt": _opt_pspecs(state_sds["opt"], policy),
                "step": jax.sharding.PartitionSpec(),
            }
            state_sh = to_shardings(state_ps, policy)
            batch_sh = to_shardings(batch_pspecs(batch_sds, policy), policy)
            step = make_train_step(api, opt, dtype=jnp.bfloat16)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
            rec["state_bytes_global"] = _tree_bytes(state_sds)
        elif shape.mode == "prefill":
            params_sds = jax.eval_shape(
                lambda: api.init(jax.random.PRNGKey(0)))
            params_sds = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                if x.dtype == jnp.float32 else x, params_sds)
            params_sh = to_shardings(param_pspecs(params_sds, policy), policy)
            batch_sh = to_shardings(batch_pspecs(batch_sds, policy), policy)
            step = make_prefill_step(api, dtype=jnp.bfloat16)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_sds, batch_sds)
            rec["state_bytes_global"] = _tree_bytes(params_sds)
        else:  # decode
            params_sds = jax.eval_shape(
                lambda: api.init(jax.random.PRNGKey(0)))
            params_sds = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
                if x.dtype == jnp.float32 else x, params_sds)
            cache_sds = _sds_tree(cache_specs(cfg, shape))
            params_sh = to_shardings(param_pspecs(params_sds, policy), policy)
            cache_sh = to_shardings(cache_pspecs(cache_sds, policy), policy)
            batch_sh = to_shardings(batch_pspecs(batch_sds, policy), policy)
            step = make_serve_step(api, long_context=long_context,
                                   dtype=jnp.bfloat16)
            jitted = jax.jit(step, in_shardings=(params_sh, cache_sh,
                                                 batch_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, batch_sds)
            rec["state_bytes_global"] = _tree_bytes(params_sds)
            rec["cache_bytes_global"] = _tree_bytes(cache_sds)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    n_chips = mesh.devices.size
    rec["chips"] = int(n_chips)
    mem = _mem_analysis(compiled)
    if mem:
        rec["memory_analysis"] = mem
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
    except Exception:
        pass

    # Trip-count-weighted accounting over the partitioned module (XLA's own
    # cost_analysis counts while bodies once — useless for scanned models).
    hlo = compiled.as_text()
    parsed = hlo_analyze(hlo)
    flops = parsed["flops"]
    byts = parsed["hbm_bytes"]
    rec["hlo_flops_per_chip"] = flops
    rec["hlo_bytes_per_chip"] = byts
    rec["collectives_bytes"] = parsed["collective_bytes"]
    rec["collectives_bytes"]["total_weighted"] = \
        parsed["collective_total_weighted"]
    rec["hlo_num_lines"] = hlo.count("\n")

    terms = roofline_terms(flops, byts,
                           parsed["collective_total_weighted"], HW_V5E)
    mf = model_flops(cfg, shape, shape.mode)
    terms["model_flops_global"] = mf
    terms["model_flops_per_chip"] = mf / n_chips
    terms["useful_flops_ratio"] = (mf / n_chips / flops) if flops else 0.0
    rec["roofline"] = terms
    rec["status"] = "ok"
    return rec


def _opt_pspecs(opt_sds, policy):
    """Adam m/v mirror the param partitioning; count is replicated."""
    from jax.sharding import PartitionSpec as P
    out = {}
    for k, v in opt_sds.items():
        if k == "count":
            out[k] = P()
        else:
            out[k] = param_pspecs(v, policy)
    return out


def combos(only_arch=None, only_shape=None, only_mesh=None):
    for arch in list_archs():
        cfg = get_config(arch)
        if cfg.kind == "classifier":
            continue
        if only_arch and arch != only_arch:
            continue
        for shape in SHAPES:
            if only_shape and shape != only_shape:
                continue
            for mesh in ("pod1", "pod2"):
                if only_mesh and mesh != only_mesh:
                    continue
                yield arch, shape, mesh


def art_path(arch, shape, mesh, suffix="") -> Path:
    return ART_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--summary", action="store_true")
    ap.add_argument("--policy", default="2d",
                    choices=["2d", "fsdp", "ep", "auto"],
                    help="sharding scheme (§Perf); 'auto' applies the "
                         "§Perf recommendation (fsdp for train shapes, "
                         "2d otherwise); artifacts for non-default "
                         "policies get an __<policy> suffix")
    args = ap.parse_args()

    ART_DIR.mkdir(parents=True, exist_ok=True)
    if args.summary:
        rows = []
        for p in sorted(ART_DIR.glob("*.json")):
            rec = json.loads(p.read_text())
            r = rec.get("roofline", {})
            rows.append((rec["arch"], rec["shape"], rec["mesh"],
                         rec["status"],
                         r.get("compute_s"), r.get("memory_s"),
                         r.get("collective_s"), r.get("bottleneck"),
                         r.get("useful_flops_ratio")))
        hdr = ("arch", "shape", "mesh", "status", "compute_s", "memory_s",
               "collective_s", "bottleneck", "useful_ratio")
        print(",".join(hdr))
        for row in rows:
            print(",".join("" if v is None else
                           (f"{v:.4g}" if isinstance(v, float) else str(v))
                           for v in row))
        return

    todo = list(combos(args.arch, args.shape, args.mesh))
    if not todo:
        raise SystemExit("nothing to do")
    suffix = "" if args.policy == "2d" else f"__{args.policy}"
    for arch, shape, mesh in todo:
        # 'auto' = the §Perf production recommendation
        policy = args.policy
        if policy == "auto":
            policy = "fsdp" if SHAPES[shape].mode == "train" else "2d"
        path = art_path(arch, shape, mesh, suffix)
        if args.skip_existing and path.exists():
            rec = json.loads(path.read_text())
            if rec.get("status") in ("ok", "skipped"):
                continue
        print(f"=== dryrun {arch} x {shape} x {mesh} ({policy})",
              flush=True)
        try:
            rec = run_combo(arch, shape, mesh, policy_mode=policy)
        except Exception as e:  # record failures as artifacts too
            rec = {"arch": arch, "shape": shape, "mesh": mesh,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-4000:]}
        path.write_text(json.dumps(rec, indent=1))
        print(f"    -> {rec['status']}", flush=True)
        if rec["status"] == "error":
            print(rec["error"], flush=True)


if __name__ == "__main__":
    main()
