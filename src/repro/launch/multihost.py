"""Multi-host entrypoint for REAL TPU pods (v5e-256 per pod).

On hardware every host runs this same module; `jax.distributed
.initialize()` wires the hosts together and `jax.devices()` exposes all
256 (or 512) chips, after which the exact code paths the dry-run proved
out (`make_production_mesh`, `ShardingPolicy`, the jitted steps) run
unchanged — GSPMD is multi-host-transparent.

  # per-host (launched by launch/launch_pod.sh on every worker):
  python -m repro.launch.multihost --task train --arch qwen3-8b \
      --shape train_4k --policy fsdp [--multi-pod]

On this CPU container the module still works in --local mode (1 host,
1 device) for smoke-testing the wiring.
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=["train", "serve", "dryrun"],
                    default="dryrun")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--policy", default="2d", choices=["2d", "fsdp"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--local", action="store_true",
                    help="single-host smoke mode (no jax.distributed)")
    ap.add_argument("--coordinator", default=os.environ.get(
        "JAX_COORDINATOR", ""), help="host:port of process 0")
    ap.add_argument("--num-processes", type=int,
                    default=int(os.environ.get("JAX_NUM_PROCESSES", "0")))
    ap.add_argument("--process-id", type=int,
                    default=int(os.environ.get("JAX_PROCESS_ID", "-1")))
    args = ap.parse_args()

    import jax
    if not args.local:
        # On Cloud TPU the three args are auto-detected from metadata;
        # explicit flags/env cover bare-metal and GKE deployments.
        kw = {}
        if args.coordinator:
            kw = dict(coordinator_address=args.coordinator,
                      num_processes=args.num_processes,
                      process_id=args.process_id)
        jax.distributed.initialize(**kw)
    print(f"[host {jax.process_index()}/{jax.process_count()}] "
          f"{jax.local_device_count()} local / "
          f"{jax.device_count()} global devices", flush=True)

    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config
    from repro.launch.steps import make_prefill_step, make_serve_step, \
        make_train_step
    from repro.models import get_model, input_specs
    from repro.optim import adam
    from repro.sharding import ShardingPolicy, batch_pspecs, param_pspecs, \
        to_shardings, use_policy

    if args.local:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    else:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    cfg = get_config(args.arch)
    if args.local:
        cfg = cfg.reduced()
    shape = SHAPES[args.shape]
    policy = ShardingPolicy(mesh, mode=args.policy)
    api = get_model(cfg)

    with mesh, use_policy(policy):
        if args.task == "dryrun":
            opt = adam(1e-4)
            state_sds = jax.eval_shape(lambda: {
                "params": api.init(jax.random.PRNGKey(0)),
                "opt": opt.init(jax.eval_shape(
                    lambda: api.init(jax.random.PRNGKey(0)))),
                "step": jnp.zeros((), jnp.int32)})
            batch_sds = input_specs(cfg, shape)
            # lower+compile only (shardings as in repro.launch.dryrun,
            # GSPMD-propagated from the policy's param specs)
            step = make_train_step(api, opt, dtype=jnp.bfloat16)
            lowered = jax.jit(step).lower(state_sds, batch_sds)
            compiled = lowered.compile()
            if jax.process_index() == 0:
                print(compiled.memory_analysis())
            return
        if args.task == "train":
            opt = adam(1e-4)
            params = api.init(jax.random.PRNGKey(0))
            state = {"params": params, "opt": opt.init(params),
                     "step": jnp.zeros((), jnp.int32)}
            step = jax.jit(make_train_step(api, opt, dtype=jnp.bfloat16),
                           donate_argnums=(0,))
            import numpy as np
            rng = np.random.default_rng(0)
            B = 2 if args.local else shape.global_batch
            S = 64 if args.local else shape.seq_len
            batch = {"tokens": jnp.asarray(
                         rng.integers(0, cfg.vocab_size, (B, S)),
                         jnp.int32),
                     "targets": jnp.asarray(
                         rng.integers(0, cfg.vocab_size, (B, S)),
                         jnp.int32),
                     "loss_mask": jnp.ones((B, S), jnp.float32)}
            for i in range(args.steps):
                state, metrics = step(state, batch)
                if jax.process_index() == 0:
                    print(f"step {i}: loss="
                          f"{float(metrics['ce_loss']):.4f}", flush=True)
            return
        raise SystemExit("serve task: use repro.launch.serve per host")


if __name__ == "__main__":
    main()
