"""Batched serving driver: prefill a batch of requests, then decode with
the GQA flash-decode path.  On CPU this runs reduced configs; on TPU the
same code pjit's over the production mesh with the sharding policy used
by the dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = get_model(cfg)
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    params = api.init(key)

    B, S = args.batch, args.prompt_len
    if cfg.kind == "vlm":
        P = cfg.vlm.num_patches
        batch = {"patches": jnp.asarray(
                     rng.normal(size=(B, P, cfg.vlm.patch_embed_dim)),
                     jnp.float32),
                 "tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, S - P)), jnp.int32)}
    elif cfg.kind == "audio":
        F = min(cfg.encdec.max_source_frames, S)
        batch = {"frames": jnp.asarray(
                     rng.normal(size=(B, F, cfg.d_model)), jnp.float32),
                 "tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    else:
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}

    mesh = make_host_mesh()
    prefill = jax.jit(make_prefill_step(api, dtype=jnp.float32,
                                        cache_extra=args.gen))
    serve = jax.jit(make_serve_step(api, dtype=jnp.float32),
                    donate_argnums=(1,))
    with mesh:
        t0 = time.time()
        token, cache = prefill(params, batch)
        token.block_until_ready()
        t_prefill = time.time() - t0
        out_tokens = [np.asarray(token)]
        t0 = time.time()
        pos = S
        for i in range(args.gen - 1):
            token, cache = serve(params, cache,
                                 {"token": token,
                                  "pos": jnp.asarray(pos, jnp.int32)})
            out_tokens.append(np.asarray(token))
            pos += 1
        token.block_until_ready()
        t_decode = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   "
          f"decode: {t_decode/max(1,args.gen-1)*1e3:.1f} ms/token")
    print("generated token ids (first request):", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
