"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e target: 16x16 (256 chips) per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
