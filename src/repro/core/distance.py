"""Heterogeneity-aware pairwise distance (paper §3.3, Eq. 9).

    Distance(u, k) = arccos( <Δb_u, Δb_k> / (|Δb_u||Δb_k|) )
                     + λ |Ĥ(D_u) − Ĥ(D_k)|

computed on output-layer bias updates only — O(N²·C) total, versus the
O(N²·|θ|) Gram matrix that Clustered Sampling [11] builds on full
gradients.  For LLM heads (C up to 256k) the Gram product is a real
matmul; ``repro/kernels/pairwise`` provides the MXU-tiled Pallas kernel
with the arccos + λ|ΔĤ| epilogue fused; this module is the jnp
reference used on CPU and by the kernel's allclose tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hetero import estimate_entropy


def pairwise_arccos(updates: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """arccos of the row-wise cosine-similarity matrix.

    updates: (N, C).  Returns (N, N) angles in [0, π].  The diagonal is
    exactly 0 (clipped before arccos so autodiff/NaNs never appear).
    """
    norms = jnp.linalg.norm(updates, axis=-1, keepdims=True)
    unit = updates / jnp.clip(norms, eps, None)
    cos = unit @ unit.T
    cos = jnp.clip(cos, -1.0 + 1e-7, 1.0 - 1e-7)
    ang = jnp.arccos(cos)
    return ang * (1.0 - jnp.eye(updates.shape[0], dtype=ang.dtype))


def distance_matrix(updates: jnp.ndarray, temperature: float,
                    lam: float = 10.0,
                    entropies: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. 9 pairwise distance over N clients' bias updates (N, C).

    ``entropies`` may be supplied (e.g. from the Pallas entropy kernel);
    otherwise they are recomputed here via Eq. 7.
    """
    if entropies is None:
        entropies = estimate_entropy(updates, temperature)
    ang = pairwise_arccos(updates)
    dh = jnp.abs(entropies[:, None] - entropies[None, :])
    return ang + lam * dh
