"""Two-stage hierarchical clustered sampling (paper §3.4, Eq. 10).

Stage 1 — pick a cluster m with probability
    π_m^t = exp(γ^t H̄_m^t) / Σ_m' exp(γ^t H̄_m'^t)
where H̄_m is the mean *estimated* entropy of the cluster's clients and
γ^t = γ⁰(1 − t/T) anneals from heterogeneity-greedy to uniform.

Stage 2 — pick a client k inside the cluster with probability
    p̃_k = p_k / Σ_{j∈G_m} p_j        (p_k ∝ |B_k| by default).

Selection of K clients repeats the two stages without replacement
(a drawn client is removed; an emptied cluster is renormalized away),
matching Algorithm 1's `while |S^t| < K` loop.

Two implementations live here:

  * numpy (``hierarchical_sample``, ``anneal``, ...) — the original
    host-side reference, kept for analysis helpers and benchmarks;
  * device (``*_device``) — pure-jax Gumbel formulations used by the
    functional selector protocol (``repro.core.selectors.functional``)
    so the entire select step stays jit/scan/vmap-compatible.  Sampling
    K items without replacement with probs ∝ w is realized as Gumbel
    top-K over log w (successive-sampling equivalence); the two-stage
    scheme draws one Gumbel argmax per stage inside a ``fori_loop``.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def anneal(gamma0: float, t: int, total_rounds: int) -> float:
    """γ^t = γ⁰ (1 − t/T), clipped at 0."""
    return float(gamma0 * max(0.0, 1.0 - t / max(1, total_rounds)))


def cluster_probs(mean_entropies: np.ndarray, gamma_t: float) -> np.ndarray:
    """π^t over clusters (Eq. 10 left), numerically stable softmax."""
    z = gamma_t * np.asarray(mean_entropies, dtype=np.float64)
    z = z - np.max(z)
    e = np.exp(z)
    return e / np.sum(e)


def hierarchical_sample(rng: np.random.Generator,
                        labels: np.ndarray,
                        mean_entropies: np.ndarray,
                        weights: np.ndarray,
                        k: int,
                        gamma_t: float) -> List[int]:
    """Draw K distinct client indices via the two-stage scheme.

    labels: (N,) cluster id per client; mean_entropies: (M,) H̄_m;
    weights: (N,) p_k (need not be normalized); k: number to select.
    """
    n = len(labels)
    k = min(k, n)
    m = int(np.max(labels)) + 1 if n else 0
    avail = [list(np.flatnonzero(labels == c)) for c in range(m)]
    pi = cluster_probs(mean_entropies, gamma_t)
    w = np.asarray(weights, dtype=np.float64)
    chosen: List[int] = []
    while len(chosen) < k:
        mask = np.array([len(a) > 0 for a in avail], dtype=np.float64)
        probs = pi * mask
        s = probs.sum()
        if s <= 0:
            probs = mask / mask.sum()
        else:
            probs = probs / s
        c = int(rng.choice(m, p=probs))
        cand = avail[c]
        pw = w[cand]
        pw = pw / pw.sum() if pw.sum() > 0 else np.full(len(cand),
                                                        1.0 / len(cand))
        pick = int(rng.choice(len(cand), p=pw))
        chosen.append(cand.pop(pick))
    return chosen


# ---------------------------------------------------------------------------
# Device-side (pure jax) variants for the jitted selection path
# ---------------------------------------------------------------------------

_NEG_LOG_FLOOR = 1e-30   # log-clip so zero weights become ~ -inf, not nan


def anneal_device(gamma0, t, total_rounds):
    """γ^t = γ⁰ (1 − t/T), traced-``t`` version of :func:`anneal`."""
    return gamma0 * jnp.maximum(0.0, 1.0 - t / jnp.maximum(1.0, total_rounds))


def gumbel_topk(key: jax.Array, logits: jnp.ndarray, k: int) -> jnp.ndarray:
    """Top-K of ``logits + Gumbel noise`` — i.e. K draws without
    replacement with P(i first) ∝ exp(logits_i)."""
    g = jax.random.gumbel(key, logits.shape, dtype=logits.dtype)
    return jax.lax.top_k(logits + g, k)[1]


def weighted_sample_device(key: jax.Array, weights: jnp.ndarray,
                           k: int) -> jnp.ndarray:
    """min(K, N) distinct indices ∝ weights (Gumbel top-K over log w)."""
    logw = jnp.log(jnp.clip(weights, _NEG_LOG_FLOOR, None))
    return gumbel_topk(key, logw.astype(jnp.float32),
                       min(k, weights.shape[-1]))


def coverage_sweep_device(key: jax.Array, seen: jnp.ndarray,
                          k: int) -> jnp.ndarray:
    """min(K, N) distinct indices, uniformly among unseen clients first
    (Alg. 1 lines 14-15), topping up uniformly from the seen pool if
    fewer than K remain unseen."""
    g = jax.random.gumbel(key, seen.shape, dtype=jnp.float32)
    return jax.lax.top_k(g + jnp.where(seen, 0.0, 1e6),
                         min(k, seen.shape[-1]))[1]


def hierarchical_sample_device(key: jax.Array, labels: jnp.ndarray,
                               mean_entropies: jnp.ndarray,
                               weights: jnp.ndarray, k: int,
                               gamma_t) -> jnp.ndarray:
    """Pure-jax two-stage sampler (Eq. 10), K sequential two-stage draws
    without replacement inside a ``fori_loop``.

    Stage 1 is a Gumbel argmax over γ^t·H̄ restricted to clusters that
    still have available clients (argmax is invariant to the softmax
    normalization, so the restriction IS the renormalization the numpy
    version performs).  Stage 2 is a Gumbel argmax over log p_k within
    the chosen cluster.  Distributionally identical to
    :func:`hierarchical_sample`, including the k = min(k, N) clamp.
    """
    n = labels.shape[0]
    k = min(k, n)
    m = mean_entropies.shape[0]
    logw = jnp.log(jnp.clip(weights, _NEG_LOG_FLOOR, None)
                   ).astype(jnp.float32)
    ent = jnp.asarray(mean_entropies, jnp.float32)

    def body(i, carry):
        avail, chosen, key = carry
        key, kc, kj = jax.random.split(key, 3)
        live = jax.ops.segment_sum(avail.astype(jnp.float32), labels,
                                   num_segments=m) > 0
        clogit = jnp.where(live, gamma_t * ent, -jnp.inf)
        c = jnp.argmax(clogit + jax.random.gumbel(kc, (m,), jnp.float32))
        jlogit = jnp.where((labels == c) & avail, logw, -jnp.inf)
        j = jnp.argmax(jlogit + jax.random.gumbel(kj, (n,), jnp.float32))
        return avail.at[j].set(False), chosen.at[i].set(j), key

    avail0 = jnp.ones(n, bool)
    chosen0 = jnp.zeros(k, jnp.int32)
    _, chosen, _ = jax.lax.fori_loop(
        0, k, body, (avail0, chosen0, key))
    return chosen


def sampling_probabilities(labels: np.ndarray, mean_entropies: np.ndarray,
                           weights: np.ndarray,
                           gamma_t: float) -> np.ndarray:
    """Single-draw marginal ω_k^t = π_{m(k)} · p_k / Σ_{j∈G_m} p_j.

    Used by the convergence-analysis benchmark (§3.5 discussion: ω_k^t ∝
    p_k exp(γ^t Ĥ_k) when clusters are entropy-pure).
    """
    pi = cluster_probs(mean_entropies, gamma_t)
    w = np.asarray(weights, dtype=np.float64)
    out = np.zeros(len(labels), dtype=np.float64)
    for c in np.unique(labels):
        sel = labels == c
        denom = w[sel].sum()
        if denom > 0:
            out[sel] = pi[c] * w[sel] / denom
        else:
            out[sel] = pi[c] / sel.sum()
    return out
