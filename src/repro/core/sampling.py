"""Two-stage hierarchical clustered sampling (paper §3.4, Eq. 10).

Stage 1 — pick a cluster m with probability
    π_m^t = exp(γ^t H̄_m^t) / Σ_m' exp(γ^t H̄_m'^t)
where H̄_m is the mean *estimated* entropy of the cluster's clients and
γ^t = γ⁰(1 − t/T) anneals from heterogeneity-greedy to uniform.

Stage 2 — pick a client k inside the cluster with probability
    p̃_k = p_k / Σ_{j∈G_m} p_j        (p_k ∝ |B_k| by default).

Selection of K clients repeats the two stages without replacement
(a drawn client is removed; an emptied cluster is renormalized away),
matching Algorithm 1's `while |S^t| < K` loop.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def anneal(gamma0: float, t: int, total_rounds: int) -> float:
    """γ^t = γ⁰ (1 − t/T), clipped at 0."""
    return float(gamma0 * max(0.0, 1.0 - t / max(1, total_rounds)))


def cluster_probs(mean_entropies: np.ndarray, gamma_t: float) -> np.ndarray:
    """π^t over clusters (Eq. 10 left), numerically stable softmax."""
    z = gamma_t * np.asarray(mean_entropies, dtype=np.float64)
    z = z - np.max(z)
    e = np.exp(z)
    return e / np.sum(e)


def hierarchical_sample(rng: np.random.Generator,
                        labels: np.ndarray,
                        mean_entropies: np.ndarray,
                        weights: np.ndarray,
                        k: int,
                        gamma_t: float) -> List[int]:
    """Draw K distinct client indices via the two-stage scheme.

    labels: (N,) cluster id per client; mean_entropies: (M,) H̄_m;
    weights: (N,) p_k (need not be normalized); k: number to select.
    """
    n = len(labels)
    k = min(k, n)
    m = int(np.max(labels)) + 1 if n else 0
    avail = [list(np.flatnonzero(labels == c)) for c in range(m)]
    pi = cluster_probs(mean_entropies, gamma_t)
    w = np.asarray(weights, dtype=np.float64)
    chosen: List[int] = []
    while len(chosen) < k:
        mask = np.array([len(a) > 0 for a in avail], dtype=np.float64)
        probs = pi * mask
        s = probs.sum()
        if s <= 0:
            probs = mask / mask.sum()
        else:
            probs = probs / s
        c = int(rng.choice(m, p=probs))
        cand = avail[c]
        pw = w[cand]
        pw = pw / pw.sum() if pw.sum() > 0 else np.full(len(cand),
                                                        1.0 / len(cand))
        pick = int(rng.choice(len(cand), p=pw))
        chosen.append(cand.pop(pick))
    return chosen


def sampling_probabilities(labels: np.ndarray, mean_entropies: np.ndarray,
                           weights: np.ndarray,
                           gamma_t: float) -> np.ndarray:
    """Single-draw marginal ω_k^t = π_{m(k)} · p_k / Σ_{j∈G_m} p_j.

    Used by the convergence-analysis benchmark (§3.5 discussion: ω_k^t ∝
    p_k exp(γ^t Ĥ_k) when clusters are entropy-pure).
    """
    pi = cluster_probs(mean_entropies, gamma_t)
    w = np.asarray(weights, dtype=np.float64)
    out = np.zeros(len(labels), dtype=np.float64)
    for c in np.unique(labels):
        sel = labels == c
        denom = w[sel].sum()
        if denom > 0:
            out[sel] = pi[c] * w[sel] / denom
        else:
            out[sel] = pi[c] / sel.sum()
    return out
