"""HiCS-FL core: the paper's contribution as composable server-side pieces.

  hetero     — Eq. 6/7 heterogeneity estimation from output-layer updates
  distance   — Eq. 9 heterogeneity-aware pairwise distance
  clustering — numpy agglomerative (Ward / average / complete / single)
  sampling   — Eq. 10 two-stage annealed cluster/client sampler
  selectors  — HiCS-FL (Alg. 1) + 5 baselines behind one API
"""
from repro.core.clustering import agglomerate, cluster_means
from repro.core.distance import distance_matrix, pairwise_arccos
from repro.core.hetero import (delta_b_from_head_delta,
                               dissimilarity_envelope,
                               entropy_separation_bound, estimate_entropy,
                               expected_bias_update, head_bias_update,
                               head_bias_updates_stacked, label_entropy,
                               softmax_entropy)
from repro.core.sampling import (anneal, cluster_probs, hierarchical_sample,
                                 sampling_probabilities)
from repro.core.selectors import (SELECTORS, ClientSelector,
                                  ClusteredSamplingSelector, DivFLSelector,
                                  FedCorSelector, HiCSFLSelector,
                                  PowerOfChoiceSelector, RandomSelector,
                                  make_selector)

__all__ = [
    "agglomerate", "cluster_means", "distance_matrix", "pairwise_arccos",
    "delta_b_from_head_delta", "dissimilarity_envelope",
    "entropy_separation_bound", "estimate_entropy", "expected_bias_update",
    "head_bias_update", "head_bias_updates_stacked", "label_entropy",
    "softmax_entropy", "anneal",
    "cluster_probs", "hierarchical_sample", "sampling_probabilities",
    "SELECTORS", "ClientSelector", "ClusteredSamplingSelector",
    "DivFLSelector", "FedCorSelector", "HiCSFLSelector",
    "PowerOfChoiceSelector", "RandomSelector", "make_selector",
]
