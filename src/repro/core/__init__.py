"""HiCS-FL core: the paper's contribution as composable server-side pieces.

  hetero     — Eq. 6/7 heterogeneity estimation from output-layer updates
  distance   — Eq. 9 heterogeneity-aware pairwise distance
  clustering — numpy agglomerative (Ward / average / complete / single)
  sampling   — Eq. 10 two-stage annealed cluster/client sampler
  selectors  — HiCS-FL (Alg. 1) + 5 baselines behind one API
"""
from repro.core.clustering import (agglomerate, agglomerate_device,
                                   cluster_means, cluster_means_device)
from repro.core.distance import distance_matrix, pairwise_arccos
from repro.core.hetero import (delta_b_from_head_delta,
                               dissimilarity_envelope,
                               entropy_separation_bound, estimate_entropy,
                               expected_bias_update, head_bias_update,
                               head_bias_updates_stacked, head_num_classes,
                               label_entropy, softmax_entropy)
from repro.core.sampling import (anneal, anneal_device, cluster_probs,
                                 coverage_sweep_device, gumbel_topk,
                                 hierarchical_sample,
                                 hierarchical_sample_device,
                                 sampling_probabilities,
                                 weighted_sample_device)
from repro.core.selectors import (FUNCTIONAL, SELECTORS, ClientSelector,
                                  ClusteredSamplingSelector, DivFLSelector,
                                  FedCorSelector, FunctionalSelector,
                                  HiCSFLSelector, Observations,
                                  PowerOfChoiceSelector, RandomSelector,
                                  SelectorState, make_functional,
                                  make_selector)

__all__ = [
    "agglomerate", "agglomerate_device", "cluster_means",
    "cluster_means_device", "distance_matrix", "pairwise_arccos",
    "delta_b_from_head_delta", "dissimilarity_envelope",
    "entropy_separation_bound", "estimate_entropy", "expected_bias_update",
    "head_bias_update", "head_bias_updates_stacked", "head_num_classes",
    "label_entropy",
    "softmax_entropy", "anneal", "anneal_device",
    "cluster_probs", "coverage_sweep_device", "gumbel_topk",
    "hierarchical_sample", "hierarchical_sample_device",
    "sampling_probabilities", "weighted_sample_device",
    "FUNCTIONAL", "SELECTORS", "ClientSelector",
    "ClusteredSamplingSelector", "DivFLSelector", "FedCorSelector",
    "FunctionalSelector", "HiCSFLSelector", "Observations",
    "PowerOfChoiceSelector", "RandomSelector", "SelectorState",
    "make_functional", "make_selector",
]
