"""Client selectors: HiCS-FL (Algorithm 1) + the paper's five baselines.

One uniform server-side API:

    sel = make_selector("hics", num_clients=N, num_select=K,
                        total_rounds=T, weights=p, temperature=T_soft)
    ids = sel.select(t)                       # round t's participant set
    sel.update(t, ids, bias_updates=..., full_updates=..., losses=...)

``requires`` declares what the server must compute for the selector each
round — this is the bookkeeping behind the Table 3 overhead comparison:

    random   : nothing
    pow-d    : losses of ALL clients (ideal setting, App. A.1.2)
    cs       : full model updates of participants  (O(|θ|) clustering)
    divfl    : full model updates of ALL clients   (ideal setting)
    fedcor   : losses of ALL clients in the warm-up stage (GP fit)
    hics     : bias updates of participants        (O(C) — the paper)

All selectors are pure numpy server logic; nothing here touches the
mesh.  HiCS-FL's O(C) hot path (entropy + norms + pairwise Eq. 9) is
one fused, jitted selection step (``repro.kernels.hics_selection_step``)
— a single pre-Gram HBM sweep over (N, C), Pallas on TPU.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.clustering import agglomerate, cluster_means
from repro.core.hetero import estimate_entropy
from repro.core.sampling import anneal, hierarchical_sample
from repro.kernels import hics_selection_step

# ---------------------------------------------------------------------------
# Base
# ---------------------------------------------------------------------------


class ClientSelector:
    """Interface; subclasses override select() and update()."""

    name = "base"
    #: what the server must compute each round: subset of
    #: {"loss_all", "full_all", "full_sel", "bias_sel"}
    requires: frozenset = frozenset()

    def __init__(self, num_clients: int, num_select: int, total_rounds: int,
                 weights: Optional[Sequence[float]] = None, seed: int = 0,
                 **_kw):
        self.n = int(num_clients)
        self.k = int(num_select)
        self.total_rounds = int(total_rounds)
        w = np.ones(self.n) if weights is None else np.asarray(
            weights, dtype=np.float64)
        self.weights = w / w.sum()
        self.rng = np.random.default_rng(seed)
        self.select_seconds = 0.0      # cumulative selection compute time
        self.update_seconds = 0.0

    # -- public API ---------------------------------------------------------
    def select(self, t: int) -> List[int]:
        t0 = time.perf_counter()
        out = self._select(t)
        self.select_seconds += time.perf_counter() - t0
        return out

    def update(self, t: int, selected: Sequence[int], *,
               bias_updates: Optional[np.ndarray] = None,
               full_updates: Optional[np.ndarray] = None,
               losses: Optional[np.ndarray] = None) -> None:
        t0 = time.perf_counter()
        self._update(t, list(selected), bias_updates=bias_updates,
                     full_updates=full_updates, losses=losses)
        self.update_seconds += time.perf_counter() - t0

    # -- to override ---------------------------------------------------------
    def _select(self, t: int) -> List[int]:
        raise NotImplementedError

    def _update(self, t, selected, **kw) -> None:
        pass

    # -- helpers -------------------------------------------------------------
    def _weighted_without_replacement(self, k: int,
                                      w: Optional[np.ndarray] = None
                                      ) -> List[int]:
        w = self.weights if w is None else w
        w = np.asarray(w, dtype=np.float64)
        w = w / w.sum()
        return list(self.rng.choice(self.n, size=min(k, self.n),
                                    replace=False, p=w))


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


class RandomSelector(ClientSelector):
    """FedProx-style multinomial sampling ∝ p_k, without replacement."""

    name = "random"
    requires = frozenset()

    def _select(self, t: int) -> List[int]:
        return self._weighted_without_replacement(self.k)


class PowerOfChoiceSelector(ClientSelector):
    """pow-d [8]: sample d candidates ∝ p_k, keep the K with the largest
    local loss.  Ideal setting (App. A.1.2): d = N, i.e. the server asks
    *all* clients for their current local loss each round."""

    name = "pow-d"
    requires = frozenset({"loss_all"})

    def __init__(self, *a, d: Optional[int] = None, **kw):
        super().__init__(*a, **kw)
        self.d = self.n if d is None else min(int(d), self.n)
        self._losses = np.zeros(self.n)

    def _select(self, t: int) -> List[int]:
        if not np.any(self._losses):
            return self._weighted_without_replacement(self.k)
        cand = self._weighted_without_replacement(self.d)
        cand.sort(key=lambda i: -self._losses[i])
        return cand[: self.k]

    def _update(self, t, selected, losses=None, **kw):
        if losses is not None:
            self._losses = np.asarray(losses, dtype=np.float64)


class ClusteredSamplingSelector(ClientSelector):
    """Clustered Sampling [11] (Alg. 2 flavour): cluster participants'
    model updates by cosine similarity (arccos distance), then sample one
    client per cluster uniformly.  Operates on *full* updates — O(N²|θ|)
    similarity, the cost the paper's Table 3 charges it with.  Clients
    never observed keep the zero vector and land in a shared cluster."""

    name = "cs"
    requires = frozenset({"full_sel"})

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._feats: Optional[np.ndarray] = None
        self._seen = np.zeros(self.n, dtype=bool)

    def _select(self, t: int) -> List[int]:
        # warm-up sweep: deterministic coverage like Alg. 1's first rounds
        if not np.all(self._seen):
            unseen = list(np.flatnonzero(~self._seen))
            self.rng.shuffle(unseen)
            take = unseen[: self.k]
            if len(take) < self.k:
                rest = [i for i in range(self.n) if i not in take]
                take += list(self.rng.choice(rest, self.k - len(take),
                                             replace=False))
            return take
        ang = _arccos_dist(self._feats)
        labels = agglomerate(ang, self.k, linkage="ward")
        out = []
        for m in range(self.k):
            members = np.flatnonzero(labels == m)
            if len(members) == 0:
                continue
            w = self.weights[members]
            w = w / w.sum()
            out.append(int(self.rng.choice(members, p=w)))
        while len(out) < self.k:  # merged clusters -> fill randomly
            extra = [i for i in range(self.n) if i not in out]
            out.append(int(self.rng.choice(extra)))
        return out

    def _update(self, t, selected, full_updates=None, **kw):
        if full_updates is None:
            return
        if self._feats is None:
            self._feats = np.zeros((self.n, full_updates.shape[-1]))
        for row, i in enumerate(selected):
            self._feats[i] = full_updates[row]
            self._seen[i] = True


class DivFLSelector(ClientSelector):
    """DivFL [2]: greedy facility-location submodular maximization on the
    gradient dissimilarity matrix; ideal setting = 1-step gradients from
    all clients each round."""

    name = "divfl"
    requires = frozenset({"full_all"})

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._feats: Optional[np.ndarray] = None

    def _select(self, t: int) -> List[int]:
        if self._feats is None:
            return self._weighted_without_replacement(self.k)
        # dissimilarity = euclidean distance between updates
        g = self._feats
        sq = np.sum(g * g, axis=1)
        dist = np.sqrt(np.maximum(sq[:, None] + sq[None, :] - 2 * g @ g.T,
                                  0.0))
        chosen: List[int] = []
        # facility location: minimize Σ_i min_{j∈S} dist(i, j)
        cover = np.full(self.n, np.inf)
        for _ in range(self.k):
            gains = np.sum(np.maximum(cover[None, :] - dist, 0.0), axis=1)
            gains[chosen] = -np.inf
            j = int(np.argmax(gains))
            chosen.append(j)
            cover = np.minimum(cover, dist[j])
        return chosen

    def _update(self, t, selected, full_updates=None, **kw):
        if full_updates is not None and full_updates.shape[0] == self.n:
            self._feats = np.asarray(full_updates, dtype=np.float64)


class FedCorSelector(ClientSelector):
    """FedCor [28]: model client losses with a GP; select greedily to
    maximize posterior loss-reduction.  Faithful-in-spirit compact
    implementation: RBF kernel over running loss-history embeddings,
    warm-up phase polls all clients' losses (the cost Table 3 charges),
    then greedy max-variance-reduction selection with annealing β."""

    name = "fedcor"
    requires = frozenset({"loss_all"})

    def __init__(self, *a, warmup: int = 10, beta: float = 0.9,
                 length_scale: float = 1.0, **kw):
        super().__init__(*a, **kw)
        self.warmup = int(warmup)
        self.beta = float(beta)
        self.ls = float(length_scale)
        self._hist: List[np.ndarray] = []
        self._losses = np.zeros(self.n)

    def _embed(self) -> np.ndarray:
        h = np.stack(self._hist[-8:], axis=1)  # (N, <=8)
        mu = h.mean(axis=1, keepdims=True)
        sd = h.std(axis=1, keepdims=True) + 1e-8
        return (h - mu) / sd

    def _select(self, t: int) -> List[int]:
        if t < self.warmup or len(self._hist) < 2:
            return self._weighted_without_replacement(self.k)
        x = self._embed()
        d2 = np.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1)
        kmat = np.exp(-d2 / (2 * self.ls ** 2))
        kmat = self.beta ** (t - self.warmup) * kmat \
            + (1 - self.beta ** (t - self.warmup)) * np.eye(self.n)
        var = kmat.diagonal().copy()
        cov = kmat.copy()
        chosen: List[int] = []
        for _ in range(self.k):
            # greedy: largest expected variance reduction weighted by loss
            score = var * (1.0 + self._losses)
            score[chosen] = -np.inf
            j = int(np.argmax(score))
            chosen.append(j)
            cj = cov[:, j]
            denom = cov[j, j] + 1e-8
            var = var - cj * cj / denom
            cov = cov - np.outer(cj, cj) / denom
        return chosen

    def _update(self, t, selected, losses=None, **kw):
        if losses is not None:
            self._losses = np.asarray(losses, dtype=np.float64)
            self._hist.append(self._losses.copy())


# ---------------------------------------------------------------------------
# HiCS-FL (the paper)
# ---------------------------------------------------------------------------


class HiCSFLSelector(ClientSelector):
    """Algorithm 1.

    Rounds t ≤ ⌈N/K⌉: random coverage sweep without replacement (S₀).
    Afterwards: estimate Ĥ for every client whose Δb has been observed,
    cluster with the Eq. 9 distance into M = K groups, then two-stage
    sample (Eq. 10) with annealed γ^t.
    """

    name = "hics"
    requires = frozenset({"bias_sel"})

    def __init__(self, *a, temperature: float = 0.0025, lam: float = 10.0,
                 gamma0: float = 4.0, num_clusters: Optional[int] = None,
                 linkage: str = "ward", normalize: bool = False,
                 gram_in_bf16: bool = False, **kw):
        super().__init__(*a, **kw)
        self.temperature = float(temperature)
        self.lam = float(lam)
        self.gamma0 = float(gamma0)
        self.m = int(num_clusters) if num_clusters else self.k
        self.linkage = linkage
        # beyond-paper: magnitude-invariant Ĥ (see hetero.estimate_entropy)
        self.normalize = bool(normalize)
        # 2× Gram bandwidth on the TPU kernel path (f32 accumulation)
        self.gram_in_bf16 = bool(gram_in_bf16)
        self._delta_b: Optional[np.ndarray] = None     # (N, C), zeros=unseen
        self._seen = np.zeros(self.n, dtype=bool)
        self._coverage_pool = list(range(self.n))
        self.last_entropies: Optional[np.ndarray] = None
        self.last_labels: Optional[np.ndarray] = None

    # -- Alg. 1 lines 14-15: initial coverage sweep --------------------------
    def _sweep(self) -> List[int]:
        take = min(self.k, len(self._coverage_pool))
        idx = self.rng.choice(len(self._coverage_pool), take, replace=False)
        out = [self._coverage_pool[i] for i in sorted(idx, reverse=True)]
        for i in sorted(idx, reverse=True):
            self._coverage_pool.pop(i)
        if len(out) < self.k:
            rest = [i for i in range(self.n) if i not in out]
            out += list(self.rng.choice(rest, self.k - len(out),
                                        replace=False))
        return out

    def _select(self, t: int) -> List[int]:
        if self._coverage_pool or self._delta_b is None:
            return self._sweep()
        # one fused device step: entropy + norms + Eq. 9 distance in a
        # single pre-Gram sweep over (N, C), no host round trip between
        ent_d, dist_d = hics_selection_step(
            self._delta_b, self.temperature, lam=self.lam,
            normalize=self.normalize, gram_in_bf16=self.gram_in_bf16)
        ent, dist = np.asarray(ent_d), np.asarray(dist_d)
        labels = agglomerate(dist, self.m, linkage=self.linkage)
        means = cluster_means(ent, labels, int(labels.max()) + 1)
        gamma_t = anneal(self.gamma0, t, self.total_rounds)
        self.last_entropies, self.last_labels = ent, labels
        return hierarchical_sample(self.rng, labels, means, self.weights,
                                   self.k, gamma_t)

    def _update(self, t, selected, bias_updates=None, **kw):
        if bias_updates is None:
            return
        bias_updates = np.asarray(bias_updates, dtype=np.float64)
        if self._delta_b is None:
            self._delta_b = np.zeros((self.n, bias_updates.shape[-1]))
        for row, i in enumerate(selected):
            self._delta_b[i] = bias_updates[row]   # Alg.1 line 17: replace
            self._seen[i] = True

    def estimated_entropies(self) -> Optional[np.ndarray]:
        if self._delta_b is None:
            return None
        return np.asarray(estimate_entropy(self._delta_b, self.temperature,
                                           normalize=self.normalize))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SELECTORS: Dict[str, type] = {
    "random": RandomSelector,
    "pow-d": PowerOfChoiceSelector,
    "cs": ClusteredSamplingSelector,
    "divfl": DivFLSelector,
    "fedcor": FedCorSelector,
    "hics": HiCSFLSelector,
}


def make_selector(name: str, **kw) -> ClientSelector:
    try:
        cls = SELECTORS[name]
    except KeyError:
        raise KeyError(f"unknown selector {name!r}; known: "
                       f"{sorted(SELECTORS)}") from None
    return cls(**kw)


def _arccos_dist(feats: np.ndarray, eps: float = 1e-8) -> np.ndarray:
    norms = np.linalg.norm(feats, axis=-1, keepdims=True)
    unit = feats / np.clip(norms, eps, None)
    cos = np.clip(unit @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
    ang = np.arccos(cos)
    np.fill_diagonal(ang, 0.0)
    return ang
