"""Agglomerative clustering on a precomputed distance matrix.

The paper (App. A.1.2) groups clients with "an off-the-shelf clustering
algorithm performing hierarchical clustering with Ward's Method" on the
Eq. 9 distance.  scipy is not available offline, so this is a
self-contained numpy implementation of bottom-up agglomerative
clustering with Lance–Williams distance updates:

    ward     (scipy-compatible on squared-distance semantics)
    average  (UPGMA)
    complete / single

The merge loop keeps a lazily-verified per-row minimum cache: the
cached value is always a LOWER bound on the row's true minimum (merges
only update it with ``np.minimum``), and the picked row is verified
with one row argmin — which simultaneously yields the partner column
and reproduces the naive flat-argmin tie order exactly.  Each merge is
then O(N) amortized with ~a dozen vector ops, no per-merge boolean-mask
copies, and no (N, N) argmin.  Rows retired by a merge are parked at
+inf so inactive entries never win.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

_LINKAGES = ("ward", "average", "complete", "single")


def agglomerate(dist: np.ndarray, num_clusters: int,
                linkage: str = "ward",
                precomputed: bool = False) -> np.ndarray:
    """Cluster N items into ``num_clusters`` groups.

    dist: (N, N) symmetric distance matrix (diagonal ignored).
    Returns integer labels (N,) in [0, num_clusters), relabelled by
    first appearance for determinism.  ``precomputed=True`` promises an
    already exactly-symmetric matrix (e.g. the incremental selection
    cache, or a kernel-produced Eq. 9 matrix) and skips the defensive
    ``0.5·(d + dᵀ)`` pass — a numerical no-op on symmetric input, so
    labels are identical either way.
    """
    if linkage not in _LINKAGES:
        raise ValueError(f"linkage must be one of {_LINKAGES}")
    n = dist.shape[0]
    if dist.shape != (n, n):
        raise ValueError(f"distance matrix must be square, got {dist.shape}")
    num_clusters = max(1, min(num_clusters, n))

    # Work on a copy with +inf diagonal; ward operates on squared dists
    # (Lance–Williams ward update is exact in d² space).
    d = np.array(dist, dtype=np.float64)
    if not precomputed:
        d = 0.5 * (d + d.T)
    if linkage == "ward":
        d = d ** 2
    np.fill_diagonal(d, np.inf)

    sizes = np.ones(n, dtype=np.float64)
    # merge forest: parent[j] = i records "cluster j absorbed into i"
    # (always i < j); labels resolve by chasing parents once at the end
    parent = np.arange(n)
    merges = n - num_clusters
    # Lazily-verified nearest-pair cache.  Invariant: row_min[k] ≤ true
    # min of row k for every live row.  Improvements are folded in
    # eagerly (np.minimum); entries that a merge RAISED (the cached
    # best edge pointed at one of the merged clusters) are left
    # stale-low and repaired only if the row is ever picked: the verify
    # argmin over the actual row exposes the true minimum.
    row_min = d.min(axis=1)
    for _ in range(merges):
        while True:
            i = int(np.argmin(row_min))
            j = int(np.argmin(d[i]))        # true row min + tie column
            true_min = d[i, j]
            if true_min == row_min[i]:
                break
            row_min[i] = true_min           # was stale-low: repair, retry
        if i > j:
            i, j = j, i
        dij = d[i, j]
        ni, nj = sizes[i], sizes[j]
        # Lance–Williams update of d(k, i∪j), vectorized over ALL k:
        # retired/self entries are +inf and stay +inf through each
        # formula (no inf−inf terms arise), so no mask copy is needed.
        di, dj = d[i], d[j]
        if linkage == "ward":
            nk = sizes
            new = (ni + nk) * di
            new += (nj + nk) * dj
            new -= nk * dij
            new /= ni + nj + nk
        elif linkage == "average":
            new = ni * di
            new += nj * dj
            new /= ni + nj
        elif linkage == "complete":
            new = np.maximum(di, dj)
        else:  # single
            new = np.minimum(di, dj)
        new[i] = np.inf                      # keep the diagonal +inf
        new[j] = np.inf
        d[i, :] = new
        d[:, i] = new
        # retire j: column only — row j is never read again (row_min[j]
        # goes to +inf below so j is never picked, and row rescans read
        # other rows, whose j-th element this write covers)
        d[:, j] = np.inf
        sizes[i] = ni + nj
        sizes[j] = 0.0
        parent[j] = i

        # --- refresh the min cache (lower bounds only) ----------------
        # Other rows: fold in the new edge to the merged cluster.  Rows
        # whose old minimum sat at column i or j may now be stale-low;
        # the pick-time verify repairs them if it matters.
        np.minimum(row_min, new, out=row_min)
        row_min[i] = new.min()               # row i changed wholesale
        row_min[j] = np.inf                  # retired

    # resolve the merge forest (parents always point to lower indices,
    # so one increasing pass suffices), then relabel 0..M-1 by first
    # appearance
    labels = np.arange(n)
    for k in range(n):
        labels[k] = labels[parent[k]]
    uniq: dict = {}
    out = np.empty(n, dtype=np.int64)
    for k, lab in enumerate(labels):
        if lab not in uniq:
            uniq[lab] = len(uniq)
        out[k] = uniq[lab]
    return out


def agglomerate_device(dist: jnp.ndarray, num_clusters: int,
                       linkage: str = "ward",
                       precomputed: bool = False) -> jnp.ndarray:
    """Pure-jax agglomerative clustering — jit/scan/vmap-compatible.

    Same Lance–Williams semantics as :func:`agglomerate` (ward on
    squared distances, naive flat-argmin merge order, first-appearance
    relabelling) but with fixed shapes: N − M merges unrolled in a
    ``fori_loop``, retired rows parked at +inf.  Because merges always
    absorb the higher index into the lower, each surviving
    representative r first appears in the label vector at position r —
    so first-appearance relabelling is exactly the rank of r among the
    sorted representatives, which ``unique(size=M)`` + ``searchsorted``
    computes with static shapes.  O(N³) worst case versus the numpy
    version's amortized O(N²), but it runs on-device inside the jitted
    round loop (N ≤ a few thousand in any selection scenario).

    ``precomputed=True`` is the fast path for callers holding an
    already exactly-symmetric distance — the incremental selection
    cache and the fused Eq. 9 kernels both produce one — skipping the
    defensive ``0.5·(d + dᵀ)`` sweep over (N, N).  On symmetric input
    ``0.5·(x + x)`` is bit-exact ``x`` in f32, so the flag can never
    change labels; it only removes work.
    """
    if linkage not in _LINKAGES:
        raise ValueError(f"linkage must be one of {_LINKAGES}")
    n = dist.shape[0]
    num_clusters = max(1, min(int(num_clusters), n))
    d = jnp.asarray(dist, jnp.float32)
    if not precomputed:
        d = 0.5 * (d + d.T)
    if linkage == "ward":
        d = d * d
    d = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d)

    def body(_, carry):
        d, sizes, labels = carry
        flat = jnp.argmin(d)                 # row-major ⇒ i < j
        i, j = flat // n, flat % n
        dij = d[i, j]
        ni, nj = sizes[i], sizes[j]
        di, dj = d[i], d[j]
        if linkage == "ward":
            new = ((ni + sizes) * di + (nj + sizes) * dj
                   - sizes * dij) / (ni + nj + sizes)
        elif linkage == "average":
            new = (ni * di + nj * dj) / (ni + nj)
        elif linkage == "complete":
            new = jnp.maximum(di, dj)
        else:  # single
            new = jnp.minimum(di, dj)
        new = new.at[i].set(jnp.inf).at[j].set(jnp.inf)
        d = d.at[i, :].set(new).at[:, i].set(new)
        d = d.at[j, :].set(jnp.inf).at[:, j].set(jnp.inf)
        sizes = sizes.at[i].set(ni + nj).at[j].set(0.0)
        labels = jnp.where(labels == j, i, labels)
        return d, sizes, labels

    _, _, labels = jax.lax.fori_loop(
        0, n - num_clusters, body,
        (d, jnp.ones(n, jnp.float32), jnp.arange(n)))
    reps = jnp.unique(labels, size=num_clusters)
    return jnp.searchsorted(reps, labels).astype(jnp.int32)


def cluster_means_device(values: jnp.ndarray, labels: jnp.ndarray,
                         num_clusters: int) -> jnp.ndarray:
    """Per-cluster mean via ``segment_sum`` (device analogue of
    :func:`cluster_means`; empty clusters get 0)."""
    s = jax.ops.segment_sum(values, labels, num_segments=num_clusters)
    c = jax.ops.segment_sum(jnp.ones_like(values), labels,
                            num_segments=num_clusters)
    return jnp.where(c > 0, s / jnp.maximum(c, 1.0), 0.0)


def cluster_means(values: np.ndarray, labels: np.ndarray,
                  num_clusters: int) -> np.ndarray:
    """Per-cluster mean of a per-item scalar (e.g. estimated entropy)."""
    out = np.zeros(num_clusters, dtype=np.float64)
    for m in range(num_clusters):
        sel = labels == m
        out[m] = float(np.mean(values[sel])) if np.any(sel) else 0.0
    return out


def silhouette_hint(dist: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette over items (diagnostic only; not used to select)."""
    n = dist.shape[0]
    uniq = np.unique(labels)
    if len(uniq) < 2:
        return 0.0
    s = []
    for i in range(n):
        same = labels == labels[i]
        same[i] = False
        a = float(np.mean(dist[i, same])) if np.any(same) else 0.0
        b = min(float(np.mean(dist[i, labels == m]))
                for m in uniq if m != labels[i])
        denom = max(a, b)
        s.append(0.0 if denom == 0 else (b - a) / denom)
    return float(np.mean(s))
