"""Agglomerative clustering on a precomputed distance matrix.

The paper (App. A.1.2) groups clients with "an off-the-shelf clustering
algorithm performing hierarchical clustering with Ward's Method" on the
Eq. 9 distance.  scipy is not available offline, so this is a
self-contained numpy implementation of bottom-up agglomerative
clustering with Lance–Williams distance updates:

    ward     (scipy-compatible on squared-distance semantics)
    average  (UPGMA)
    complete / single

O(N³) naive nearest-pair search — plenty for N ≤ a few thousand clients
(selection happens once per round, server-side).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

_LINKAGES = ("ward", "average", "complete", "single")


def agglomerate(dist: np.ndarray, num_clusters: int,
                linkage: str = "ward") -> np.ndarray:
    """Cluster N items into ``num_clusters`` groups.

    dist: (N, N) symmetric distance matrix (diagonal ignored).
    Returns integer labels (N,) in [0, num_clusters), relabelled by
    first appearance for determinism.
    """
    if linkage not in _LINKAGES:
        raise ValueError(f"linkage must be one of {_LINKAGES}")
    n = dist.shape[0]
    if dist.shape != (n, n):
        raise ValueError(f"distance matrix must be square, got {dist.shape}")
    num_clusters = max(1, min(num_clusters, n))

    # Work on a copy with +inf diagonal; ward operates on squared dists
    # (Lance–Williams ward update is exact in d² space).
    d = np.array(dist, dtype=np.float64)
    d = 0.5 * (d + d.T)
    if linkage == "ward":
        d = d ** 2
    np.fill_diagonal(d, np.inf)

    active = np.ones(n, dtype=bool)
    sizes = np.ones(n, dtype=np.int64)
    labels = np.arange(n)
    merges = n - num_clusters
    for _ in range(merges):
        flat = np.argmin(d)
        i, j = np.unravel_index(flat, d.shape)
        if i > j:
            i, j = j, i
        # Lance–Williams update of d(k, i∪j) for all active k != i, j
        ni, nj = sizes[i], sizes[j]
        k_mask = active.copy()
        k_mask[i] = k_mask[j] = False
        dik, djk = d[i, k_mask], d[j, k_mask]
        if linkage == "ward":
            nk = sizes[k_mask].astype(np.float64)
            tot = ni + nj + nk
            new = ((ni + nk) * dik + (nj + nk) * djk - nk * d[i, j]) / tot
        elif linkage == "average":
            new = (ni * dik + nj * djk) / (ni + nj)
        elif linkage == "complete":
            new = np.maximum(dik, djk)
        else:  # single
            new = np.minimum(dik, djk)
        d[i, k_mask] = new
        d[k_mask, i] = new
        d[j, :] = np.inf
        d[:, j] = np.inf
        active[j] = False
        sizes[i] = ni + nj
        labels[labels == labels[j]] = labels[i]

    # relabel 0..M-1 by first appearance
    uniq: dict = {}
    out = np.empty(n, dtype=np.int64)
    for idx, lab in enumerate(labels):
        if lab not in uniq:
            uniq[lab] = len(uniq)
        out[idx] = uniq[lab]
    return out


def cluster_means(values: np.ndarray, labels: np.ndarray,
                  num_clusters: int) -> np.ndarray:
    """Per-cluster mean of a per-item scalar (e.g. estimated entropy)."""
    out = np.zeros(num_clusters, dtype=np.float64)
    for m in range(num_clusters):
        sel = labels == m
        out[m] = float(np.mean(values[sel])) if np.any(sel) else 0.0
    return out


def silhouette_hint(dist: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette over items (diagnostic only; not used to select)."""
    n = dist.shape[0]
    uniq = np.unique(labels)
    if len(uniq) < 2:
        return 0.0
    s = []
    for i in range(n):
        same = labels == labels[i]
        same[i] = False
        a = float(np.mean(dist[i, same])) if np.any(same) else 0.0
        b = min(float(np.mean(dist[i, labels == m]))
                for m in uniq if m != labels[i])
        denom = max(a, b)
        s.append(0.0 if denom == 0 else (b - a) / denom)
    return float(np.mean(s))
