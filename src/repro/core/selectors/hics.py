"""HiCS-FL (Algorithm 1) as a functional triple + its OO shim.

Rounds with a non-empty coverage pool: random sweep without
replacement (S₀, Alg. 1 lines 14-15).  Afterwards: agglomerative
clustering into M = K groups on the Eq. 9 distance and the two-stage
Eq. 10 sampler, all on-device (``agglomerate_device`` /
``hierarchical_sample_device``), so ``select`` is one jit-compatible
function with no host round trip — the piece that makes the fully
scanned server round loop possible.

Two distance paths feed the clustering:

* ``incremental=True`` (default) — Alg. 1 line 17 replaces only the K
  participants' Δb rows per round, so the state carries a cached
  (N, N) distance + (N, 2) [norm, Ĥ] stats and ``select`` starts by
  refreshing just the rows ``update`` staled
  (``repro.kernels.hics_selection_step_cached``): O(K·N·C) per round.
* ``incremental=False`` — the from-scratch fused device step
  (``repro.kernels.hics_selection_step``): one pre-Gram HBM sweep over
  (N, C) into the MXU-tiled Gram/arccos kernel, O(N²·C) per round.
  Kept as the parity oracle (tests/test_incremental_selection.py locks
  the two paths together) and for drivers that mutate Δb out-of-band.

The cache refresh runs at the top of any select with pending staleness
(``state.stale_fill > 0``) — including coverage-sweep rounds — and
covers the whole staled-id ring (``stale_slots`` cohorts' worth, one
by default); refreshing an already-fresh row is idempotent, so both
the strict select→update alternation of the sync drivers and the
buffered-async server's skipped/merged updates keep the cache exact.
(Contract: at most ``stale_slots``·K ids staled between ``select``s —
the ring's capacity.)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.clustering import agglomerate_device, cluster_means_device
from repro.core.hetero import estimate_entropy
from repro.core.sampling import (anneal_device, coverage_sweep_device,
                                 hierarchical_sample_device)
from repro.core.selectors.base import ClientSelector
from repro.core.selectors.functional import (FunctionalSelector,
                                             Observations, SelectorState,
                                             init_state, mark_seen,
                                             stale_append, stale_clear,
                                             take_key)
from repro.kernels import hics_selection_step, hics_selection_step_cached

REQUIRES = frozenset({"bias_sel"})


def hics_functional(num_clients: int, num_select: int, total_rounds: int,
                    weights=None, temperature: float = 0.0025,
                    lam: float = 10.0, gamma0: float = 4.0,
                    num_clusters: Optional[int] = None,
                    linkage: str = "ward", normalize: bool = False,
                    gram_in_bf16: bool = False, num_classes: int = 1,
                    incremental: bool = True, stale_slots: int = 1,
                    **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)
    m = int(num_clusters) if num_clusters else k
    temperature = float(temperature)
    lam, gamma0 = float(lam), float(gamma0)
    tr = float(total_rounds)
    num_classes = max(1, int(num_classes))
    incremental = bool(incremental)
    stale_len = k * max(1, int(stale_slots))

    def init(key) -> SelectorState:
        return init_state(key, n, weights, num_classes=num_classes,
                          dist_cache=incremental,
                          stale_len=stale_len if incremental else 0)

    def select(state: SelectorState, t, key=None):
        state, key = take_key(state, key)

        if incremental:
            # ring refresh of the cached distance/stats (idempotent on
            # fresh rows) — the only Δb-dependent compute of the
            # round.  Skipped entirely when no update staled anything
            # since the last refresh (async ticks without an
            # aggregation, masked empty cohorts).
            def _refresh(_):
                _, d, s = hics_selection_step_cached(
                    state.delta_b, state.dist_cache, state.row_stats,
                    state.stale_ids, temperature, lam=lam,
                    normalize=normalize, gram_in_bf16=gram_in_bf16)
                return d, s

            dist_c, stats_c = jax.lax.cond(
                state.stale_fill > 0, _refresh,
                lambda _: (state.dist_cache, state.row_stats), 0)
            state = stale_clear(state._replace(
                dist_cache=dist_c, row_stats=stats_c))

        def sweep(key):
            ids = coverage_sweep_device(key, state.seen, k)
            return ids, state.seen.at[ids].set(True)

        def clustered(key):
            if incremental:
                ent, dist = state.row_stats[:, 1], state.dist_cache
            else:
                ent, dist = hics_selection_step(
                    state.delta_b, temperature, lam=lam,
                    normalize=normalize, gram_in_bf16=gram_in_bf16)
            # the cache scatter (and the fused kernel) keep the matrix
            # exactly symmetric, so clustering may skip re-symmetrizing
            labels = agglomerate_device(dist, m, linkage=linkage,
                                        precomputed=True)
            means = cluster_means_device(ent, labels, m)
            gamma_t = anneal_device(gamma0, t, tr)
            ids = hierarchical_sample_device(
                key, labels, means, state.weights, k, gamma_t)
            return ids, state.seen

        ids, seen = jax.lax.cond(state.unseen_count > 0, sweep,
                                 clustered, key)
        state = state._replace(
            seen=seen, unseen_count=jnp.sum(~seen).astype(jnp.int32))
        return ids, state

    def update(state: SelectorState, t, ids, obs: Observations
               ) -> SelectorState:
        if obs.bias_updates is None:
            return state
        db = state.delta_b.at[ids].set(          # Alg. 1 line 17: replace
            jnp.asarray(obs.bias_updates, state.delta_b.dtype))
        state = mark_seen(state._replace(
            delta_b=db, hist_count=state.hist_count + 1), ids)
        if incremental:
            # stale the replaced rows; the next select refreshes them
            state = stale_append(state, ids)
        return state

    def entropies(state: SelectorState) -> jnp.ndarray:
        return estimate_entropy(state.delta_b, temperature,
                                normalize=normalize)

    def diagnostics(state: SelectorState) -> dict:
        # clustering-health observables for the telemetry ``selection``
        # group: re-cluster the cached Eq. 9 distance (incremental path
        # only — from-scratch mode has no resident distance to read)
        # and report cluster sizes + the within-cluster Ĥ RMS spread.
        ent = state.row_stats[:, 1]
        labels = agglomerate_device(state.dist_cache, m, linkage=linkage,
                                    precomputed=True)
        means = cluster_means_device(ent, labels, m)
        return {
            "cluster_sizes": jnp.bincount(labels, length=m),
            "cluster_ent_spread": jnp.sqrt(
                jnp.mean(jnp.square(ent - means[labels]))),
        }

    return FunctionalSelector("hics", REQUIRES, init, select, update,
                              jit_capable=True, entropies=entropies,
                              diagnostics=diagnostics if incremental
                              else None)


class HiCSFLSelector(ClientSelector):
    """Algorithm 1 — thin shim over :func:`hics_functional`."""

    name = "hics"
    requires = REQUIRES

    def _make_functional(self, **kw) -> FunctionalSelector:
        return hics_functional(**kw)

    @property
    def _delta_b(self) -> jnp.ndarray:
        """Back-compat view of the device-resident Δb buffer (N, C)."""
        return self.state.delta_b
