"""HiCS-FL (Algorithm 1) as a functional triple + its OO shim.

Rounds with a non-empty coverage pool: random sweep without
replacement (S₀, Alg. 1 lines 14-15).  Afterwards: one fused device
step (``repro.kernels.hics_selection_step``) produces Ĥ and the Eq. 9
distance in a single pre-Gram HBM sweep over (N, C); agglomerative
clustering into M = K groups and the two-stage Eq. 10 sampler then run
on-device too (``agglomerate_device`` / ``hierarchical_sample_device``)
so ``select`` is one jit-compatible function with no host round trip —
the piece that makes the fully-scanned server round loop possible.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.clustering import agglomerate_device, cluster_means_device
from repro.core.hetero import estimate_entropy
from repro.core.sampling import (anneal_device, coverage_sweep_device,
                                 hierarchical_sample_device)
from repro.core.selectors.base import ClientSelector
from repro.core.selectors.functional import (FunctionalSelector,
                                             Observations, SelectorState,
                                             init_state, mark_seen, take_key)
from repro.kernels import hics_selection_step

REQUIRES = frozenset({"bias_sel"})


def hics_functional(num_clients: int, num_select: int, total_rounds: int,
                    weights=None, temperature: float = 0.0025,
                    lam: float = 10.0, gamma0: float = 4.0,
                    num_clusters: Optional[int] = None,
                    linkage: str = "ward", normalize: bool = False,
                    gram_in_bf16: bool = False, num_classes: int = 1,
                    **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)
    m = int(num_clusters) if num_clusters else k
    temperature = float(temperature)
    lam, gamma0 = float(lam), float(gamma0)
    tr = float(total_rounds)
    num_classes = max(1, int(num_classes))

    def init(key) -> SelectorState:
        return init_state(key, n, weights, num_classes=num_classes)

    def select(state: SelectorState, t, key=None):
        state, key = take_key(state, key)

        def sweep(key):
            ids = coverage_sweep_device(key, state.seen, k)
            return ids, state.seen.at[ids].set(True)

        def clustered(key):
            ent, dist = hics_selection_step(
                state.delta_b, temperature, lam=lam,
                normalize=normalize, gram_in_bf16=gram_in_bf16)
            labels = agglomerate_device(dist, m, linkage=linkage)
            means = cluster_means_device(ent, labels, m)
            gamma_t = anneal_device(gamma0, t, tr)
            ids = hierarchical_sample_device(
                key, labels, means, state.weights, k, gamma_t)
            return ids, state.seen

        ids, seen = jax.lax.cond(state.unseen_count > 0, sweep,
                                 clustered, key)
        state = state._replace(
            seen=seen, unseen_count=jnp.sum(~seen).astype(jnp.int32))
        return ids, state

    def update(state: SelectorState, t, ids, obs: Observations
               ) -> SelectorState:
        if obs.bias_updates is None:
            return state
        db = state.delta_b.at[ids].set(          # Alg. 1 line 17: replace
            jnp.asarray(obs.bias_updates, state.delta_b.dtype))
        state = mark_seen(state._replace(
            delta_b=db, hist_count=state.hist_count + 1), ids)
        return state

    def entropies(state: SelectorState) -> jnp.ndarray:
        return estimate_entropy(state.delta_b, temperature,
                                normalize=normalize)

    return FunctionalSelector("hics", REQUIRES, init, select, update,
                              jit_capable=True, entropies=entropies)


class HiCSFLSelector(ClientSelector):
    """Algorithm 1 — thin shim over :func:`hics_functional`."""

    name = "hics"
    requires = REQUIRES

    def _make_functional(self, **kw) -> FunctionalSelector:
        return hics_functional(**kw)

    @property
    def _delta_b(self) -> jnp.ndarray:
        """Back-compat view of the device-resident Δb buffer (N, C)."""
        return self.state.delta_b
