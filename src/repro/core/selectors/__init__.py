"""Client selectors: HiCS-FL (Algorithm 1) + the paper's five baselines.

Two equivalent API surfaces over one functional core:

**Functional protocol** (``functional.py``) — each selector is an
``(init, select, update)`` triple over an explicit, device-resident
:class:`SelectorState` pytree:

    fn = make_functional("hics", num_clients=N, num_select=K,
                         total_rounds=T, weights=p)
    state = fn.init(jax.random.PRNGKey(0))
    ids, state = fn.select(state, t, key)          # pure, jit-compatible
    state = fn.update(state, t, ids, Observations(bias_updates=dbs))

``select``/``update`` are pure and jit/scan/vmap-compatible, so
``FederatedServer(jit_rounds=True)`` runs whole rounds — select →
vmapped local update → aggregate → stacked Δb → selector update — as
one scanned ``round_step`` with zero host transfers, and multi-seed
experiment sweeps batch as one ``vmap`` over stacked states.
:class:`Observations` is the typed container the server produces
on-device each round (replacing the old ``bias_updates=/full_updates=/
losses=`` kwarg soup).

**OO shims** (``base.py`` + per-selector classes) — the historical
stateful API, now thin wrappers holding the state pytree and a PRNG
key:

    sel = make_selector("hics", num_clients=N, num_select=K,
                        total_rounds=T, weights=p)
    ids = sel.select(t)
    sel.update(t, ids, bias_updates=...)           # legacy kwargs ok

``requires`` declares what the server must compute per round — the
bookkeeping behind the Table 3 overhead comparison:

    random   : nothing
    pow-d    : losses of ALL clients (ideal setting, App. A.1.2)
    cs       : full model updates of participants  (O(|θ|) clustering)
    divfl    : full model updates of ALL clients   (ideal setting;
               refresh="selected" polls participants only)
    fedcor   : losses of ALL clients in the warm-up stage (GP fit)
    hics     : bias updates of participants        (O(C) — the paper)

All four requirement classes are computable inside the jitted round
step, so EVERY selector rides the scanned server loop
(``jit_rounds=True``) and the vmapped multi-seed sweep engine
(``repro.scenarios``).

HiCS-FL's O(C) hot path (entropy + norms + pairwise Eq. 9) is
INCREMENTAL by default: the state carries a cached distance matrix and
``select`` refreshes only the K rows the last ``update`` replaced
(``repro.kernels.hics_selection_step_cached`` — O(K·N·C) per round;
``incremental=False`` restores the from-scratch fused step
``hics_selection_step``, O(N²·C)), followed by on-device clustering
(``agglomerate_device``, ``precomputed=True`` fast path) and Gumbel
two-stage sampling (``hierarchical_sample_device``).  The full-update
selectors (cs/divfl) get the same treatment over their (N, F) feature
buffers — ``repro.kernels.cached_feature_step`` with the selector's
own cosine/L2 epilogue, plus a ``proj_dim`` feature-hashing knob that
keeps |θ|-sized features bounded (see ``baselines.py``).
"""
from repro.core.selectors.base import ClientSelector
from repro.core.selectors.baselines import (ClusteredSamplingSelector,
                                            DivFLSelector, FedCorSelector,
                                            PowerOfChoiceSelector,
                                            RandomSelector, cs_functional,
                                            divfl_functional,
                                            fedcor_functional,
                                            powd_functional,
                                            random_functional)
from repro.core.selectors.functional import (FunctionalSelector,
                                             Observations, SelectorState,
                                             init_state)
from repro.core.selectors.hics import HiCSFLSelector, hics_functional
from repro.core.selectors.registry import (FUNCTIONAL, SELECTORS,
                                           make_functional, make_selector)

__all__ = [
    "ClientSelector", "ClusteredSamplingSelector", "DivFLSelector",
    "FedCorSelector", "HiCSFLSelector", "PowerOfChoiceSelector",
    "RandomSelector", "FunctionalSelector", "Observations",
    "SelectorState", "init_state", "FUNCTIONAL", "SELECTORS",
    "make_functional", "make_selector", "hics_functional",
    "random_functional", "powd_functional", "cs_functional",
    "divfl_functional", "fedcor_functional",
]
