"""OO shim layer: the legacy ``ClientSelector`` API over the
functional core.

Every selector class is now a thin stateful wrapper around its
:class:`~repro.core.selectors.functional.FunctionalSelector` triple:
``select``/``update`` keep their historical signatures (including the
``bias_updates=/full_updates=/losses=`` kwargs, now folded into an
:class:`Observations`), the wrapper owns the ``SelectorState`` pytree
and a PRNG key, and the transitions are jitted once per shape.  Callers
that migrate can reach the functional core directly via ``sel.fn`` /
``sel.state`` — or skip the class entirely with
``repro.core.make_functional``.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selectors.functional import (FunctionalSelector,
                                             Observations, SelectorState,
                                             state_entropies)


class ClientSelector:
    """Stateful shim; subclasses plug in a functional factory.

    ``requires`` declares what the server must compute for the selector
    each round — the bookkeeping behind the Table 3 overhead
    comparison: subset of {"loss_all", "full_all", "full_sel",
    "bias_sel"}.
    """

    name = "base"
    requires: frozenset = frozenset()

    def __init__(self, num_clients: int, num_select: int, total_rounds: int,
                 weights: Optional[Sequence[float]] = None, seed: int = 0,
                 **kw):
        self.n = int(num_clients)
        self.k = int(num_select)
        self.total_rounds = int(total_rounds)
        w = np.ones(self.n) if weights is None else np.asarray(
            weights, dtype=np.float64)
        self.weights = w / w.sum()
        self.fn: FunctionalSelector = self._make_functional(
            num_clients=self.n, num_select=self.k,
            total_rounds=self.total_rounds, weights=self.weights, **kw)
        # the functional core owns the truth: factory kwargs can move a
        # selector between requirement classes (e.g. divfl's
        # refresh="selected" polls participants instead of everyone),
        # so the instance shadows the class-level default
        self.requires = self.fn.requires
        self._key = jax.random.PRNGKey(int(seed))
        self._key, k0 = jax.random.split(self._key)
        self.state: SelectorState = self.fn.init(k0)
        self._select_jit = jax.jit(self.fn.select)
        self._update_jit = jax.jit(self.fn.update)
        self.select_seconds = 0.0      # cumulative selection compute time
        self.update_seconds = 0.0
        # incremental-cache hazard tracking: the staled-id ring holds
        # stale_slots·K ids, so updates staling more than that without
        # an intervening select would silently wrap around and leave
        # the earliest cohort's cached rows stale — fail fast instead
        # (host-side only; the raw functional API documents the same
        # contract)
        self._stale_pending = 0

    # -- functional factory (override) ---------------------------------------
    def _make_functional(self, **kw) -> FunctionalSelector:
        raise NotImplementedError

    # -- public API ---------------------------------------------------------
    def select(self, t: int, key: Optional[jax.Array] = None) -> List[int]:
        """Round t's participant set.  ``key`` overrides the shim's own
        PRNG stream (the server passes the round key so the host loop
        and the scanned loop draw identically)."""
        t0 = time.perf_counter()
        if key is None:
            self._key, key = jax.random.split(self._key)
        ids, self.state = self._select_jit(self.state, t, key)
        self._stale_pending = 0            # select refreshed the cache
        out = [int(i) for i in np.asarray(ids)]
        self.select_seconds += time.perf_counter() - t0
        return out

    def update(self, t: int, selected: Sequence[int],
               observations: Optional[Observations] = None, *,
               bias_updates=None, full_updates=None, losses=None) -> None:
        t0 = time.perf_counter()
        req = self.fn.requires
        if observations is not None:
            obs = observations
        else:
            # only materialize the fields this selector's `requires`
            # declares — callers hand every kwarg to every selector,
            # and converting an ignored (K, |θ|) array would dominate
            # the very overhead Table 3 measures
            obs = Observations(
                bias_updates=jnp.asarray(bias_updates, jnp.float32)
                if bias_updates is not None and "bias_sel" in req
                else None,
                full_updates=jnp.asarray(full_updates, jnp.float32)
                if full_updates is not None
                and req & {"full_all", "full_sel"} else None,
                losses=jnp.asarray(losses, jnp.float32)
                if losses is not None and "loss_all" in req else None)
        ids = jnp.asarray(list(selected), jnp.int32)
        # an update stales cached rows when the selector carries a
        # staleness buffer and this observation writes the buffer it
        # caches over (Δb for hics, full-update features for cs/divfl)
        ring = int(self.state.stale_ids.shape[0])
        stales = ring and (
            (obs.bias_updates is not None and "bias_sel" in req)
            or (obs.full_updates is not None
                and bool(req & {"full_all", "full_sel"})))
        if stales:
            if self._stale_pending + len(ids) > ring:
                raise RuntimeError(
                    f"{self.name}: update() would stale "
                    f"{self._stale_pending + len(ids)} cached rows but "
                    f"the staled-id ring holds {ring} — ids from an "
                    "earlier cohort would be overwritten and their "
                    "rows silently stay stale without an "
                    "intervening select(). Call select() between "
                    "updates, construct the selector with a larger "
                    "stale_slots, or with incremental=False.")
            self._stale_pending += len(ids)
        self.state = self._ensure_dims(self.state, obs)
        self.state = self._update_jit(self.state, t, ids, obs)
        self.update_seconds += time.perf_counter() - t0

    # -- helpers -------------------------------------------------------------
    def _ensure_dims(self, state: SelectorState,
                     obs: Observations) -> SelectorState:
        """Grow zero-width state buffers to the observed feature widths
        (standalone use — the server sizes them at init).  Only buffers
        this selector's ``requires`` actually reads are grown; an
        unused (N, |θ|) buffer would otherwise ride the state pytree
        through every jitted transition."""
        req = self.fn.requires
        if (obs.bias_updates is not None and "bias_sel" in req
                and state.delta_b.shape[1] != obs.bias_updates.shape[-1]):
            state = state._replace(delta_b=jnp.zeros(
                (self.n, obs.bias_updates.shape[-1]), jnp.float32))
        if obs.full_updates is not None and req & {"full_all", "full_sel"}:
            # the stored width can differ from the observed width when
            # the selector down-projects (fn.feat_width maps P -> F)
            fw = self.fn.feat_width or (lambda p: p)
            want = fw(obs.full_updates.shape[-1])
            if state.feats.shape[1] != want:
                state = state._replace(feats=jnp.zeros(
                    (self.n, want), jnp.float32))
        return state

    def estimated_entropies(self) -> Optional[np.ndarray]:
        """Latest Ĥ per client, or None before any Δb was observed.
        Same extraction as the scanned loop and the telemetry
        ``selection`` group — all routes go through
        :func:`~repro.core.selectors.functional.state_entropies`."""
        if int(self.state.hist_count) == 0:
            return None
        ent = state_entropies(self.fn, self.state)
        return np.asarray(ent) if ent.shape[0] else None
