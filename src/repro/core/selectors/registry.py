"""Selector registries: OO shim classes and functional factories.

    sel = make_selector("hics", num_clients=N, num_select=K,
                        total_rounds=T, weights=p, temperature=T_soft)
    ids = sel.select(t)
    sel.update(t, ids, bias_updates=...)

    fn = make_functional("hics", num_clients=N, num_select=K,
                         total_rounds=T, weights=p, temperature=T_soft)
    state = fn.init(jax.random.PRNGKey(0))
    ids, state = fn.select(state, t, key)
    state = fn.update(state, t, ids, Observations(bias_updates=...))

Both registries accept a uniform kwarg surface — unknown hyper-kwargs
are ignored by selectors that don't use them, so callers can pass one
kwargs dict for any selector name.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.selectors.base import ClientSelector
from repro.core.selectors.baselines import (ClusteredSamplingSelector,
                                            DivFLSelector, FedCorSelector,
                                            PowerOfChoiceSelector,
                                            RandomSelector, cs_functional,
                                            divfl_functional,
                                            fedcor_functional,
                                            powd_functional,
                                            random_functional)
from repro.core.selectors.functional import FunctionalSelector
from repro.core.selectors.hics import HiCSFLSelector, hics_functional

SELECTORS: Dict[str, type] = {
    "random": RandomSelector,
    "pow-d": PowerOfChoiceSelector,
    "cs": ClusteredSamplingSelector,
    "divfl": DivFLSelector,
    "fedcor": FedCorSelector,
    "hics": HiCSFLSelector,
}

FUNCTIONAL: Dict[str, Callable[..., FunctionalSelector]] = {
    "random": random_functional,
    "pow-d": powd_functional,
    "cs": cs_functional,
    "divfl": divfl_functional,
    "fedcor": fedcor_functional,
    "hics": hics_functional,
}


def make_selector(name: str, **kw) -> ClientSelector:
    """Build an OO shim selector by name."""
    try:
        cls = SELECTORS[name]
    except KeyError:
        raise KeyError(f"unknown selector {name!r}; known: "
                       f"{sorted(SELECTORS)}") from None
    return cls(**kw)


def make_functional(name: str, **kw) -> FunctionalSelector:
    """Build a functional (init, select, update) triple by name."""
    try:
        factory = FUNCTIONAL[name]
    except KeyError:
        raise KeyError(f"unknown selector {name!r}; known: "
                       f"{sorted(FUNCTIONAL)}") from None
    return factory(**kw)
