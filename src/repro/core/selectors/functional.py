"""The functional selector protocol: pytree state, pure transitions.

A selector is a ``FunctionalSelector`` triple

    state = fn.init(key)                       # SelectorState pytree
    ids, state = fn.select(state, t, key)      # pure, jit-compatible
    state = fn.update(state, t, ids, obs)      # pure, jit-compatible

operating on an explicit :class:`SelectorState` pytree.  Every field is
a device array, so a whole federated round (select → vmapped local
update → aggregate → stacked Δb → selector update) jits into one
``round_step`` that ``FederatedServer`` can drive through ``lax.scan``
with zero host transfers — and whole experiments (multi-seed sweeps)
become one ``vmap`` over stacked states.

:class:`Observations` replaces the legacy ``bias_updates=/
full_updates=/losses=`` kwarg soup: the server produces it on-device
and ``update`` consumes whichever fields the selector's ``requires``
declares.  Unused fields stay ``None`` (an empty pytree — the
structure is static per trace).

Shape/staticness contract: client count N, cohort size K, cluster
count M, and the feature widths C/P are fixed at construction
(closures of the triple); hyper-parameters that only scale arithmetic
(γ⁰, T, λ) are plain floats baked into the closure.  The state carries
only per-experiment *data* — Δb buffer, seen-mask/coverage pool,
feature buffer, loss history ring, client weights, PRNG key — which is
exactly what varies across the experiments a ``vmap`` batches.
"""
from __future__ import annotations

from typing import Callable, FrozenSet, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Observations(NamedTuple):
    """What the server computed for the selector this round.

    bias_updates : (K, C) Δb (or bias-free ΔW surrogate) of the round's
                   participants, row-aligned with ``ids`` — HiCS-FL.
    full_updates : (K, P) or (N, P) flattened model updates — CS/DivFL.
    losses       : (N,) current global-model loss per client — pow-d,
                   FedCor.
    """
    bias_updates: Optional[jnp.ndarray] = None
    full_updates: Optional[jnp.ndarray] = None
    losses: Optional[jnp.ndarray] = None


class SelectorState(NamedTuple):
    """One pytree carrying every selector's round-to-round data.

    Selectors use the subset of fields they need; unused array fields
    are allocated with a zero-width trailing axis so the pytree
    structure is uniform and cheap.  The coverage pool is represented
    as (seen mask, unseen count) — an O(N) packed form equivalent to an
    explicit shrinking id list, but scatter/reduce-friendly under jit.
    """
    key: jax.Array            # PRNG key (used when select gets key=None)
    weights: jnp.ndarray      # (N,) normalized p_k
    seen: jnp.ndarray         # (N,) bool — coverage pool complement
    unseen_count: jnp.ndarray  # () int32
    delta_b: jnp.ndarray      # (N, C) device-resident Δb buffer
    feats: jnp.ndarray        # (N, P) full-update buffer
    losses: jnp.ndarray       # (N,) latest loss poll
    loss_hist: jnp.ndarray    # (H, N) loss-history ring (newest last)
    hist_count: jnp.ndarray   # () int32 — observations received
    # --- incremental-selection cache (hics incremental=True; width 0
    # otherwise).  Alg. 1 replaces K Δb rows per round, so the Eq. 9
    # distance and the per-row [norm, Ĥ] stats are cached and only the
    # refreshed rows recomputed (O(K·N·C) vs O(N²·C) per round).
    dist_cache: jnp.ndarray   # (N, N) cached Eq. 9 distance (or (N, 0))
    row_stats: jnp.ndarray    # (N, 2) cached [L2 norm, Ĥ] (or (N, 0))
    # per-client staleness: a ring of the ids whose cached rows
    # `update` wrote since the last refresh.  (L,) int32 with
    # L = stale_slots·K (one slot-cohort by default), or (0,).
    # `stale_fill` counts ids appended since the last refresh — the
    # next `select` refreshes the whole ring iff it is > 0, then
    # resets it (slots beyond the fill hold previously refreshed ids;
    # re-refreshing a fresh row is idempotent, so the over-refresh is
    # harmless).
    stale_ids: jnp.ndarray
    stale_fill: jnp.ndarray   # () int32 — ids appended since last refresh


class FunctionalSelector(NamedTuple):
    """(init, select, update) + metadata; see the module docstring."""
    name: str
    requires: FrozenSet[str]
    init: Callable[[jax.Array], SelectorState]
    select: Callable[..., tuple]     # (state, t, key=None) -> (ids, state)
    update: Callable[..., SelectorState]  # (state, t, ids, obs) -> state
    jit_capable: bool = True
    #: optional (state) -> (N,) Ĥ, for history recording inside the scan
    entropies: Optional[Callable[[SelectorState], jnp.ndarray]] = None
    #: optional (state) -> {"cluster_sizes": (M,), "cluster_ent_spread":
    #: ()} — clustering-health observables for the telemetry
    #: ``selection`` group.  Pure/jit-compatible like ``entropies``.
    diagnostics: Optional[Callable[[SelectorState], dict]] = None
    #: optional observed-full-update-width -> stored-feature-width map.
    #: Selectors that down-project |θ|-sized updates (cs/divfl with
    #: ``proj_dim``) store features narrower than the observations; the
    #: OO shim's lazy buffer growth sizes ``state.feats`` through this.
    feat_width: Optional[Callable[[int], int]] = None


def init_state(key: jax.Array, num_clients: int, weights=None,
               num_classes: int = 0, feat_dim: int = 0,
               hist_len: int = 0, dist_cache: bool = False,
               stale_len: int = 0) -> SelectorState:
    """Allocate a fresh :class:`SelectorState` with the given widths.

    ``dist_cache=True`` sizes the incremental-selection cache — an
    (N, N) distance matrix plus (N, 2) row stats — and ``stale_len``
    the staleness index buffer (the selector's K).  The cache starts at
    zero: every entry is rewritten by a K-row refresh before the first
    clustered selection reads it (a client only leaves the coverage
    pool by participating, which stales — then refreshes — its rows).
    """
    n = int(num_clients)
    w = (jnp.ones(n, jnp.float32) if weights is None
         else jnp.asarray(weights, jnp.float32))
    w = w / jnp.sum(w)
    return SelectorState(
        key=key,
        weights=w,
        seen=jnp.zeros(n, bool),
        unseen_count=jnp.int32(n),
        delta_b=jnp.zeros((n, int(num_classes)), jnp.float32),
        feats=jnp.zeros((n, int(feat_dim)), jnp.float32),
        losses=jnp.zeros(n, jnp.float32),
        loss_hist=jnp.zeros((int(hist_len), n), jnp.float32),
        hist_count=jnp.int32(0),
        dist_cache=jnp.zeros((n, n if dist_cache else 0), jnp.float32),
        row_stats=jnp.zeros((n, 2 if dist_cache else 0), jnp.float32),
        stale_ids=jnp.zeros(int(stale_len), jnp.int32),
        stale_fill=jnp.int32(0),
    )


def state_entropies(fn: FunctionalSelector,
                    state: SelectorState) -> jnp.ndarray:
    """(N,) Ĥ estimate from a selector's state, or a zero-width (0,)
    array when the selector doesn't estimate entropies.

    The single entropy-extraction point shared by the host loop
    (``ClientSelector.estimated_entropies``), the scanned round step,
    the sweep engine, and the telemetry ``selection`` group — all four
    see the same values by construction.  Pure/jit-compatible.
    """
    if fn.entropies is None:
        return jnp.zeros((0,), jnp.float32)
    return fn.entropies(state)


def take_key(state: SelectorState, key: Optional[jax.Array]):
    """Resolve select()'s key argument: an explicit key leaves the
    state's own key untouched (scan path — the server supplies the
    round's key); ``None`` splits the state key (standalone use)."""
    if key is None:
        new_key, sub = jax.random.split(state.key)
        return state._replace(key=new_key), sub
    return state, key


def mark_seen(state: SelectorState, ids: jnp.ndarray) -> SelectorState:
    """Fold ``ids`` into the coverage pool (idempotent)."""
    seen = state.seen.at[ids].set(True)
    return state._replace(
        seen=seen, unseen_count=jnp.sum(~seen).astype(jnp.int32))


def stale_append(state: SelectorState, ids) -> SelectorState:
    """Append ``ids`` to the staled-row ring the next refresh must
    cover.  Shared by every incremental selector (hics on Δb, cs/divfl
    on full-update features).

    The ring is fixed at (L,) with L = ``stale_slots``·K: appends land
    at ``stale_fill mod L`` onward and bump the fill counter, so up to
    ``stale_slots`` cohorts can accumulate between refreshes — the
    buffered-async server's out-of-order arrivals.  The refreshing
    ``select`` covers every slot (slots beyond the fill hold ids whose
    rows are already fresh; re-refreshing them is idempotent) and
    resets the counter via :func:`stale_clear`.  An empty id list
    leaves pending staleness untouched.  More than L ids in ONE call
    cannot be represented (static error); more than L ids ACROSS calls
    wrap around and silently overwrite pending entries — sizing the
    ring for the driver's update cadence is the caller's contract (the
    OO shim fails fast on that hazard host-side).
    """
    ids_arr = jnp.asarray(ids, jnp.int32).reshape(-1)
    kk = ids_arr.shape[0]
    ring = state.stale_ids.shape[0]
    if kk == 0:
        return state
    if kk > ring:
        raise ValueError(
            f"incremental selector's staleness ring holds {ring} ids "
            f"but one update staled {kk}; construct the selector with "
            "a larger stale_slots (the ring must cover the largest "
            "single cohort)")
    pos = jnp.mod(state.stale_fill + jnp.arange(kk, dtype=jnp.int32),
                  ring)
    return state._replace(
        stale_ids=state.stale_ids.at[pos].set(ids_arr),
        stale_fill=state.stale_fill + jnp.int32(kk))


def stale_clear(state: SelectorState) -> SelectorState:
    """Reset the staleness counter after a refresh covered the ring."""
    return state._replace(stale_fill=jnp.int32(0))
