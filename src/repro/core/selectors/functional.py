"""The functional selector protocol: pytree state, pure transitions.

A selector is a ``FunctionalSelector`` triple

    state = fn.init(key)                       # SelectorState pytree
    ids, state = fn.select(state, t, key)      # pure, jit-compatible
    state = fn.update(state, t, ids, obs)      # pure, jit-compatible

operating on an explicit :class:`SelectorState` pytree.  Every field is
a device array, so a whole federated round (select → vmapped local
update → aggregate → stacked Δb → selector update) jits into one
``round_step`` that ``FederatedServer`` can drive through ``lax.scan``
with zero host transfers — and whole experiments (multi-seed sweeps)
become one ``vmap`` over stacked states.

:class:`Observations` replaces the legacy ``bias_updates=/
full_updates=/losses=`` kwarg soup: the server produces it on-device
and ``update`` consumes whichever fields the selector's ``requires``
declares.  Unused fields stay ``None`` (an empty pytree — the
structure is static per trace).

Shape/staticness contract: client count N, cohort size K, cluster
count M, and the feature widths C/P are fixed at construction
(closures of the triple); hyper-parameters that only scale arithmetic
(γ⁰, T, λ) are plain floats baked into the closure.  The state carries
only per-experiment *data* — Δb buffer, seen-mask/coverage pool,
feature buffer, loss history ring, client weights, PRNG key — which is
exactly what varies across the experiments a ``vmap`` batches.
"""
from __future__ import annotations

from typing import Callable, FrozenSet, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Observations(NamedTuple):
    """What the server computed for the selector this round.

    bias_updates : (K, C) Δb (or bias-free ΔW surrogate) of the round's
                   participants, row-aligned with ``ids`` — HiCS-FL.
    full_updates : (K, P) or (N, P) flattened model updates — CS/DivFL.
    losses       : (N,) current global-model loss per client — pow-d,
                   FedCor.
    """
    bias_updates: Optional[jnp.ndarray] = None
    full_updates: Optional[jnp.ndarray] = None
    losses: Optional[jnp.ndarray] = None


class SelectorState(NamedTuple):
    """One pytree carrying every selector's round-to-round data.

    Selectors use the subset of fields they need; unused array fields
    are allocated with a zero-width trailing axis so the pytree
    structure is uniform and cheap.  The coverage pool is represented
    as (seen mask, unseen count) — an O(N) packed form equivalent to an
    explicit shrinking id list, but scatter/reduce-friendly under jit.
    """
    key: jax.Array            # PRNG key (used when select gets key=None)
    weights: jnp.ndarray      # (N,) normalized p_k
    seen: jnp.ndarray         # (N,) bool — coverage pool complement
    unseen_count: jnp.ndarray  # () int32
    delta_b: jnp.ndarray      # (N, C) device-resident Δb buffer
    feats: jnp.ndarray        # (N, P) full-update buffer
    losses: jnp.ndarray       # (N,) latest loss poll
    loss_hist: jnp.ndarray    # (H, N) loss-history ring (newest last)
    hist_count: jnp.ndarray   # () int32 — observations received
    # --- incremental-selection cache (hics incremental=True; width 0
    # otherwise).  Alg. 1 replaces K Δb rows per round, so the Eq. 9
    # distance and the per-row [norm, Ĥ] stats are cached and only the
    # refreshed rows recomputed (O(K·N·C) vs O(N²·C) per round).
    dist_cache: jnp.ndarray   # (N, N) cached Eq. 9 distance (or (N, 0))
    row_stats: jnp.ndarray    # (N, 2) cached [L2 norm, Ĥ] (or (N, 0))
    # per-client staleness: the ids whose Δb rows `update` last wrote
    # and the next `select` must refresh.  (K,) int32, or (0,).
    stale_ids: jnp.ndarray


class FunctionalSelector(NamedTuple):
    """(init, select, update) + metadata; see the module docstring."""
    name: str
    requires: FrozenSet[str]
    init: Callable[[jax.Array], SelectorState]
    select: Callable[..., tuple]     # (state, t, key=None) -> (ids, state)
    update: Callable[..., SelectorState]  # (state, t, ids, obs) -> state
    jit_capable: bool = True
    #: optional (state) -> (N,) Ĥ, for history recording inside the scan
    entropies: Optional[Callable[[SelectorState], jnp.ndarray]] = None
    #: optional observed-full-update-width -> stored-feature-width map.
    #: Selectors that down-project |θ|-sized updates (cs/divfl with
    #: ``proj_dim``) store features narrower than the observations; the
    #: OO shim's lazy buffer growth sizes ``state.feats`` through this.
    feat_width: Optional[Callable[[int], int]] = None


def init_state(key: jax.Array, num_clients: int, weights=None,
               num_classes: int = 0, feat_dim: int = 0,
               hist_len: int = 0, dist_cache: bool = False,
               stale_len: int = 0) -> SelectorState:
    """Allocate a fresh :class:`SelectorState` with the given widths.

    ``dist_cache=True`` sizes the incremental-selection cache — an
    (N, N) distance matrix plus (N, 2) row stats — and ``stale_len``
    the staleness index buffer (the selector's K).  The cache starts at
    zero: every entry is rewritten by a K-row refresh before the first
    clustered selection reads it (a client only leaves the coverage
    pool by participating, which stales — then refreshes — its rows).
    """
    n = int(num_clients)
    w = (jnp.ones(n, jnp.float32) if weights is None
         else jnp.asarray(weights, jnp.float32))
    w = w / jnp.sum(w)
    return SelectorState(
        key=key,
        weights=w,
        seen=jnp.zeros(n, bool),
        unseen_count=jnp.int32(n),
        delta_b=jnp.zeros((n, int(num_classes)), jnp.float32),
        feats=jnp.zeros((n, int(feat_dim)), jnp.float32),
        losses=jnp.zeros(n, jnp.float32),
        loss_hist=jnp.zeros((int(hist_len), n), jnp.float32),
        hist_count=jnp.int32(0),
        dist_cache=jnp.zeros((n, n if dist_cache else 0), jnp.float32),
        row_stats=jnp.zeros((n, 2 if dist_cache else 0), jnp.float32),
        stale_ids=jnp.zeros(int(stale_len), jnp.int32),
    )


def take_key(state: SelectorState, key: Optional[jax.Array]):
    """Resolve select()'s key argument: an explicit key leaves the
    state's own key untouched (scan path — the server supplies the
    round's key); ``None`` splits the state key (standalone use)."""
    if key is None:
        new_key, sub = jax.random.split(state.key)
        return state._replace(key=new_key), sub
    return state, key


def mark_seen(state: SelectorState, ids: jnp.ndarray) -> SelectorState:
    """Fold ``ids`` into the coverage pool (idempotent)."""
    seen = state.seen.at[ids].set(True)
    return state._replace(
        seen=seen, unseen_count=jnp.sum(~seen).astype(jnp.int32))


def stale_rows(state: SelectorState, ids, k: int) -> SelectorState:
    """Record ``ids`` as the cached-distance rows the next ``select``
    must refresh.  Shared by every incremental selector (hics on Δb,
    cs/divfl on full-update features).

    The buffer is fixed at (K,): shorter id lists pad by repeating the
    last id (an idempotent extra refresh); an empty list keeps the
    pending staleness (nothing new to refresh, nothing refreshed yet).
    More than K ids cannot be represented — the caller must refresh
    between updates (the OO shim fails fast on that hazard).
    """
    ids_arr = jnp.asarray(ids, jnp.int32).reshape(-1)
    kk = ids_arr.shape[0]
    if kk > k:
        raise ValueError(
            f"incremental selector can refresh at most K={k} cached "
            f"rows per round, got {kk} updated ids")
    if kk == k:
        stale = ids_arr
    elif kk == 0:
        stale = state.stale_ids
    else:
        stale = jnp.concatenate(
            [ids_arr, jnp.broadcast_to(ids_arr[-1:], (k - kk,))])
    return state._replace(stale_ids=stale)
