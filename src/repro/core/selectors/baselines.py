"""The paper's five baseline selectors as functional triples + shims.

    random : FedProx-style multinomial ∝ p_k without replacement
    pow-d  : sample d candidates ∝ p_k, keep the K largest-loss [8]
    cs     : Clustered Sampling [11] — arccos clustering of FULL updates
    divfl  : DivFL [2] — greedy facility location on update distances
    fedcor : FedCor [28] — GP over loss-history embeddings

All five are expressed in pure jax over the shared ``SelectorState``
pytree, so the OO shims and the functional path draw from the same
transition functions.  CS and DivFL operate on |θ|-sized features
(``full_sel`` / ``full_all``) — the O(N²|θ|) cost Table 3 charges them
with — so the server's scanned round loop excludes them
(``jit_capable=False`` there refers to the scan-carry footprint, not
to traceability: the transitions themselves jit fine).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.clustering import agglomerate_device
from repro.core.sampling import coverage_sweep_device, weighted_sample_device
from repro.core.selectors.base import ClientSelector
from repro.core.selectors.functional import (FunctionalSelector,
                                             init_state, mark_seen, take_key)

_LOG_FLOOR = 1e-30


# ---------------------------------------------------------------------------
# random
# ---------------------------------------------------------------------------


def random_functional(num_clients: int, num_select: int, total_rounds: int,
                      weights=None, **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)

    def init(key):
        return init_state(key, n, weights)

    def select(state, t, key=None):
        state, key = take_key(state, key)
        return weighted_sample_device(key, state.weights, k), state

    def update(state, t, ids, obs):
        return state

    return FunctionalSelector("random", frozenset(), init, select, update)


# ---------------------------------------------------------------------------
# pow-d
# ---------------------------------------------------------------------------


def powd_functional(num_clients: int, num_select: int, total_rounds: int,
                    weights=None, d: Optional[int] = None,
                    **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)
    d = n if d is None else min(int(d), n)

    def init(key):
        return init_state(key, n, weights)

    def select(state, t, key=None):
        state, key = take_key(state, key)

        def cold(key):
            return weighted_sample_device(key, state.weights, k)

        def warm(key):
            cand = weighted_sample_device(key, state.weights, d)
            in_cand = jnp.zeros(n, bool).at[cand].set(True)
            masked = jnp.where(in_cand, state.losses, -jnp.inf)
            return jax.lax.top_k(masked, k)[1]

        ids = jax.lax.cond(jnp.any(state.losses != 0), warm, cold, key)
        return ids, state

    def update(state, t, ids, obs):
        if obs.losses is None:
            return state
        return state._replace(losses=jnp.asarray(obs.losses, jnp.float32),
                              hist_count=state.hist_count + 1)

    return FunctionalSelector("pow-d", frozenset({"loss_all"}), init,
                              select, update)


# ---------------------------------------------------------------------------
# cs (Clustered Sampling)
# ---------------------------------------------------------------------------


def cs_functional(num_clients: int, num_select: int, total_rounds: int,
                  weights=None, feat_dim: int = 1,
                  **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)
    feat_dim = max(1, int(feat_dim))

    def init(key):
        return init_state(key, n, weights, feat_dim=feat_dim)

    def select(state, t, key=None):
        state, key = take_key(state, key)

        def warmup(key):
            # deterministic coverage like Alg. 1's first rounds
            return coverage_sweep_device(key, state.seen, k)

        def clustered(key):
            f = state.feats
            norms = jnp.linalg.norm(f, axis=-1, keepdims=True)
            unit = f / jnp.clip(norms, 1e-8, None)
            cos = jnp.clip(unit @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
            ang = jnp.arccos(cos)
            ang = jnp.where(jnp.eye(n, dtype=bool), 0.0, ang)
            # exactly symmetric by construction — skip re-symmetrizing
            labels = agglomerate_device(ang, k, linkage="ward",
                                        precomputed=True)
            # one client per cluster, ∝ p_k within the cluster
            logw = jnp.log(jnp.clip(state.weights, _LOG_FLOOR, None))
            logit = jnp.where(labels[None, :] == jnp.arange(k)[:, None],
                              logw[None, :], -jnp.inf)
            g = jax.random.gumbel(key, (k, n), jnp.float32)
            return jnp.argmax(logit + g, axis=1).astype(jnp.int32)

        ids = jax.lax.cond(state.unseen_count > 0, warmup, clustered, key)
        return ids, state

    def update(state, t, ids, obs):
        if obs.full_updates is None:
            return state
        feats = state.feats.at[ids].set(
            jnp.asarray(obs.full_updates, jnp.float32))
        return mark_seen(state._replace(
            feats=feats, hist_count=state.hist_count + 1), ids)

    return FunctionalSelector("cs", frozenset({"full_sel"}), init, select,
                              update, jit_capable=False)


# ---------------------------------------------------------------------------
# divfl
# ---------------------------------------------------------------------------


def divfl_functional(num_clients: int, num_select: int, total_rounds: int,
                     weights=None, feat_dim: int = 1,
                     **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)
    feat_dim = max(1, int(feat_dim))

    def init(key):
        return init_state(key, n, weights, feat_dim=feat_dim)

    def select(state, t, key=None):
        state, key = take_key(state, key)

        def cold(key):
            return weighted_sample_device(key, state.weights, k)

        def warm(key):
            g = state.feats
            sq = jnp.sum(g * g, axis=1)
            dist = jnp.sqrt(jnp.clip(
                sq[:, None] + sq[None, :] - 2.0 * (g @ g.T), 0.0, None))

            # greedy facility location: minimize Σ_i min_{j∈S} dist(i,j)
            def body(i, carry):
                chosen, taken, cover = carry
                gains = jnp.sum(jnp.maximum(cover[None, :] - dist, 0.0),
                                axis=1)
                j = jnp.argmax(jnp.where(taken, -jnp.inf, gains))
                return (chosen.at[i].set(j.astype(jnp.int32)),
                        taken.at[j].set(True),
                        jnp.minimum(cover, dist[j]))

            chosen, _, _ = jax.lax.fori_loop(
                0, k, body, (jnp.zeros(k, jnp.int32),
                             jnp.zeros(n, bool), jnp.full(n, jnp.inf)))
            return chosen

        ids = jax.lax.cond(state.hist_count > 0, warm, cold, key)
        return ids, state

    def update(state, t, ids, obs):
        # ideal setting: only a full (N, P) poll refreshes the features
        if obs.full_updates is None or obs.full_updates.shape[0] != n:
            return state
        return state._replace(
            feats=jnp.asarray(obs.full_updates, jnp.float32),
            hist_count=state.hist_count + 1)

    return FunctionalSelector("divfl", frozenset({"full_all"}), init,
                              select, update, jit_capable=False)


# ---------------------------------------------------------------------------
# fedcor
# ---------------------------------------------------------------------------


def fedcor_functional(num_clients: int, num_select: int, total_rounds: int,
                      weights=None, warmup: int = 10, beta: float = 0.9,
                      length_scale: float = 1.0, hist_len: int = 8,
                      **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)
    warmup, beta, ls = int(warmup), float(beta), float(length_scale)
    h_len = int(hist_len)

    def init(key):
        return init_state(key, n, weights, hist_len=h_len)

    def select(state, t, key=None):
        state, key = take_key(state, key)

        def cold(key):
            return weighted_sample_device(key, state.weights, k)

        def warm(key):
            # standardized loss-history embedding over the valid ring
            x = state.loss_hist.T                      # (N, H), newest last
            valid = (jnp.arange(h_len)
                     >= h_len - jnp.minimum(state.hist_count, h_len))
            cnt = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
            mu = jnp.sum(x * valid, axis=1, keepdims=True) / cnt
            var = jnp.sum(jnp.square((x - mu) * valid), axis=1,
                          keepdims=True) / cnt
            xs = (x - mu) / (jnp.sqrt(var) + 1e-8) * valid
            d2 = jnp.sum(jnp.square(xs[:, None, :] - xs[None, :, :]), -1)
            kmat = jnp.exp(-d2 / (2.0 * ls * ls))
            w_t = jnp.power(beta, jnp.maximum(t - warmup, 0))
            kmat = w_t * kmat + (1.0 - w_t) * jnp.eye(n)

            # greedy max variance-reduction weighted by current losses
            def body(i, carry):
                chosen, taken, var_d, cov = carry
                score = jnp.where(taken, -jnp.inf,
                                  var_d * (1.0 + state.losses))
                j = jnp.argmax(score)
                cj = cov[:, j]
                denom = cov[j, j] + 1e-8
                return (chosen.at[i].set(j.astype(jnp.int32)),
                        taken.at[j].set(True),
                        var_d - cj * cj / denom,
                        cov - jnp.outer(cj, cj) / denom)

            chosen, _, _, _ = jax.lax.fori_loop(
                0, k, body, (jnp.zeros(k, jnp.int32), jnp.zeros(n, bool),
                             jnp.diagonal(kmat), kmat))
            return chosen

        ids = jax.lax.cond((t >= warmup) & (state.hist_count >= 2),
                           warm, cold, key)
        return ids, state

    def update(state, t, ids, obs):
        if obs.losses is None:
            return state
        losses = jnp.asarray(obs.losses, jnp.float32)
        hist = jnp.roll(state.loss_hist, -1, axis=0).at[-1].set(losses)
        return state._replace(losses=losses, loss_hist=hist,
                              hist_count=state.hist_count + 1)

    return FunctionalSelector("fedcor", frozenset({"loss_all"}), init,
                              select, update)


# ---------------------------------------------------------------------------
# OO shims
# ---------------------------------------------------------------------------


class RandomSelector(ClientSelector):
    """FedProx-style multinomial sampling ∝ p_k, without replacement."""
    name = "random"
    requires = frozenset()

    def _make_functional(self, **kw):
        return random_functional(**kw)


class PowerOfChoiceSelector(ClientSelector):
    """pow-d [8], ideal setting (App. A.1.2): d = N — the server asks
    *all* clients for their current local loss each round."""
    name = "pow-d"
    requires = frozenset({"loss_all"})

    def _make_functional(self, **kw):
        return powd_functional(**kw)


class ClusteredSamplingSelector(ClientSelector):
    """Clustered Sampling [11] (Alg. 2 flavour) on *full* updates —
    the O(N²|θ|) similarity cost Table 3 charges it with."""
    name = "cs"
    requires = frozenset({"full_sel"})

    def _make_functional(self, **kw):
        return cs_functional(**kw)


class DivFLSelector(ClientSelector):
    """DivFL [2]: greedy facility-location submodular maximization;
    ideal setting = 1-step gradients from all clients each round."""
    name = "divfl"
    requires = frozenset({"full_all"})

    def _make_functional(self, **kw):
        return divfl_functional(**kw)


class FedCorSelector(ClientSelector):
    """FedCor [28]: GP over running loss-history embeddings with
    annealing β; warm-up polls all clients' losses (Table 3 cost)."""
    name = "fedcor"
    requires = frozenset({"loss_all"})

    def _make_functional(self, **kw):
        return fedcor_functional(**kw)
