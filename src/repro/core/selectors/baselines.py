"""The paper's five baseline selectors as functional triples + shims.

    random : FedProx-style multinomial ∝ p_k without replacement
    pow-d  : sample d candidates ∝ p_k, keep the K largest-loss [8]
    cs     : Clustered Sampling [11] — arccos clustering of FULL updates
    divfl  : DivFL [2] — greedy facility location on update distances
    fedcor : FedCor [28] — GP over loss-history embeddings

All five are expressed in pure jax over the shared ``SelectorState``
pytree, so the OO shims and the functional path draw from the same
transition functions — and ALL of them are ``jit_capable``: the server
scans whole rounds through ``lax.scan`` and the sweep engine vmaps
whole experiments for every selector.

CS and DivFL operate on flattened full-update features (``full_sel`` /
``full_all``) — the O(N²|θ|) similarity cost Table 3 charges them
with.  Two mechanisms keep that family honest AND device-resident:

* ``proj_dim`` bounds the (N, F) feature buffer the state carries: raw
  |θ|-wide updates are sign-hashed into F buckets (feature hashing —
  inner products are preserved in expectation, so cosine/L2 geometry
  survives), which is what makes the scan-carry footprint acceptable
  at production |θ|.  ``proj_dim=None`` stores updates verbatim.
* ``incremental=True`` gives both selectors the K-row distance caching
  HiCS got in PR 4: the state carries a cached (N, N) matrix + (N, 2)
  [norm, 0] row stats, and ``select`` refreshes only the rows the last
  ``update`` wrote (``repro.kernels.cached_feature_step`` — the strip
  kernel with the selector's own cosine/L2 epilogue), O(K·N·F) per
  round instead of O(N²·F).  ``incremental=False`` rebuilds the matrix
  from the feature buffer each round — kept as the parity oracle.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.clustering import agglomerate_device
from repro.core.sampling import coverage_sweep_device, weighted_sample_device
from repro.core.selectors.base import ClientSelector
from repro.core.selectors.functional import (FunctionalSelector,
                                             init_state, mark_seen,
                                             stale_append, stale_clear,
                                             take_key)
from repro.kernels import cached_feature_step

_LOG_FLOOR = 1e-30


def _make_projector(proj_dim: Optional[int], proj_seed: int
                    ) -> tuple[Callable, Callable[[int], int]]:
    """(project, feat_width) for the full-update selectors.

    ``project`` maps (..., P) raw flattened updates to the (..., F)
    stored features, F = min(P, proj_dim): a signed feature hash —
    Rademacher signs drawn from ``proj_seed`` (a compile-time constant,
    identical across host/scan/sweep drivers), then contiguous buckets
    summed — so ⟨h(u), h(v)⟩ is an unbiased estimate of ⟨u, v⟩ and the
    cosine/L2 distances the selectors cluster on survive the
    compression.  ``proj_dim=None`` is the identity.  ``feat_width``
    exposes the P -> F map so buffer sizing (server init, OO shim lazy
    growth) agrees with ``project`` without calling it.
    """
    if proj_dim is None:
        return (lambda u: u), (lambda p: p)
    f_cap = int(proj_dim)

    def feat_width(p: int) -> int:
        return min(int(p), f_cap)

    def project(u: jnp.ndarray) -> jnp.ndarray:
        p = u.shape[-1]
        f = feat_width(p)
        if f == p:
            return u
        signs = jax.random.rademacher(
            jax.random.PRNGKey(proj_seed), (p,), jnp.float32)
        chunk = -(-p // f)
        u = u * signs
        u = jnp.pad(u, [(0, 0)] * (u.ndim - 1) + [(0, f * chunk - p)])
        return u.reshape(u.shape[:-1] + (f, chunk)).sum(axis=-1)

    return project, feat_width


# ---------------------------------------------------------------------------
# random
# ---------------------------------------------------------------------------


def random_functional(num_clients: int, num_select: int, total_rounds: int,
                      weights=None, **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)

    def init(key):
        return init_state(key, n, weights)

    def select(state, t, key=None):
        state, key = take_key(state, key)
        return weighted_sample_device(key, state.weights, k), state

    def update(state, t, ids, obs):
        return state

    return FunctionalSelector("random", frozenset(), init, select, update)


# ---------------------------------------------------------------------------
# pow-d
# ---------------------------------------------------------------------------


def powd_functional(num_clients: int, num_select: int, total_rounds: int,
                    weights=None, d: Optional[int] = None,
                    **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)
    d = n if d is None else min(int(d), n)

    def init(key):
        return init_state(key, n, weights)

    def select(state, t, key=None):
        state, key = take_key(state, key)

        def cold(key):
            return weighted_sample_device(key, state.weights, k)

        def warm(key):
            cand = weighted_sample_device(key, state.weights, d)
            in_cand = jnp.zeros(n, bool).at[cand].set(True)
            masked = jnp.where(in_cand, state.losses, -jnp.inf)
            return jax.lax.top_k(masked, k)[1]

        ids = jax.lax.cond(jnp.any(state.losses != 0), warm, cold, key)
        return ids, state

    def update(state, t, ids, obs):
        if obs.losses is None:
            return state
        return state._replace(losses=jnp.asarray(obs.losses, jnp.float32),
                              hist_count=state.hist_count + 1)

    return FunctionalSelector("pow-d", frozenset({"loss_all"}), init,
                              select, update)


# ---------------------------------------------------------------------------
# cs (Clustered Sampling)
# ---------------------------------------------------------------------------


def cs_functional(num_clients: int, num_select: int, total_rounds: int,
                  weights=None, feat_dim: int = 1,
                  proj_dim: Optional[int] = None, proj_seed: int = 0,
                  incremental: bool = True, stale_slots: int = 1,
                  **_kw) -> FunctionalSelector:
    """Clustered Sampling [11]: ward clustering of the participants'
    full updates under the angular (arccos cosine) distance, one pick
    per cluster ∝ p_k.  ``feat_dim`` is the RAW flattened-update width
    the server observes; ``proj_dim``/``proj_seed`` bound the stored
    features, ``incremental`` enables the K-row distance cache and
    ``stale_slots`` sizes its staled-id ring (see the module
    docstring and ``functional.stale_append``)."""
    n = int(num_clients)
    k = min(int(num_select), n)
    project, feat_width = _make_projector(proj_dim, int(proj_seed))
    f_dim = max(1, feat_width(int(feat_dim)))
    incremental = bool(incremental)
    stale_len = k * max(1, int(stale_slots))

    def init(key):
        return init_state(key, n, weights, feat_dim=f_dim,
                          dist_cache=incremental,
                          stale_len=stale_len if incremental else 0)

    def select(state, t, key=None):
        state, key = take_key(state, key)

        if incremental:
            # ring refresh of the cached angular distance (idempotent
            # on fresh rows) — the only feature-dependent compute;
            # skipped when nothing staled since the last refresh
            def _refresh(_):
                return cached_feature_step(
                    state.feats, state.dist_cache, state.row_stats,
                    state.stale_ids, metric="cosine")

            dist_c, stats_c = jax.lax.cond(
                state.stale_fill > 0, _refresh,
                lambda _: (state.dist_cache, state.row_stats), 0)
            state = stale_clear(state._replace(
                dist_cache=dist_c, row_stats=stats_c))

        def warmup(key):
            # deterministic coverage like Alg. 1's first rounds
            return coverage_sweep_device(key, state.seen, k)

        def clustered(key):
            if incremental:
                ang = state.dist_cache
            else:
                f = state.feats
                norms = jnp.linalg.norm(f, axis=-1, keepdims=True)
                unit = f / jnp.clip(norms, 1e-8, None)
                cos = jnp.clip(unit @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
                ang = jnp.arccos(cos)
                ang = jnp.where(jnp.eye(n, dtype=bool), 0.0, ang)
            # exactly symmetric by construction — skip re-symmetrizing
            labels = agglomerate_device(ang, k, linkage="ward",
                                        precomputed=True)
            # one client per cluster, ∝ p_k within the cluster
            logw = jnp.log(jnp.clip(state.weights, _LOG_FLOOR, None))
            logit = jnp.where(labels[None, :] == jnp.arange(k)[:, None],
                              logw[None, :], -jnp.inf)
            g = jax.random.gumbel(key, (k, n), jnp.float32)
            return jnp.argmax(logit + g, axis=1).astype(jnp.int32)

        ids = jax.lax.cond(state.unseen_count > 0, warmup, clustered, key)
        return ids, state

    def update(state, t, ids, obs):
        if obs.full_updates is None:
            return state
        feats = state.feats.at[ids].set(
            project(jnp.asarray(obs.full_updates, jnp.float32)))
        state = mark_seen(state._replace(
            feats=feats, hist_count=state.hist_count + 1), ids)
        if incremental:
            state = stale_append(state, ids)
        return state

    return FunctionalSelector("cs", frozenset({"full_sel"}), init, select,
                              update, jit_capable=True,
                              feat_width=feat_width)


# ---------------------------------------------------------------------------
# divfl
# ---------------------------------------------------------------------------


def divfl_functional(num_clients: int, num_select: int, total_rounds: int,
                     weights=None, feat_dim: int = 1,
                     proj_dim: Optional[int] = None, proj_seed: int = 0,
                     refresh: str = "all", incremental: bool = True,
                     stale_slots: int = 1, tie_quant: float = 1e-5,
                     **_kw) -> FunctionalSelector:
    """DivFL [2]: greedy facility location on pairwise L2 distances of
    flattened updates.

    ``refresh`` picks the polling regime:

      "all"      — ideal setting (the Table 3 cost): a one-step
                   gradient from EVERY client each round replaces the
                   whole feature buffer (``requires = full_all``).
                   Every row changes per round, so the K-row cache
                   cannot help — ``incremental`` is ignored and the
                   distance matrix is built from the buffer each round.
      "selected" — practical setting: only the participants' updates
                   refresh their feature rows (``requires =
                   full_sel``), everyone else keeps a stale
                   representation — exactly the K-rows-per-round
                   pattern the distance cache accelerates, O(K·N·F).
                   A coverage sweep polls every client once before the
                   first facility-location round so no distance is ever
                   computed against a never-observed row.

    ``feat_dim`` is the RAW flattened-update width; ``proj_dim``/
    ``proj_seed`` bound the stored features (module docstring);
    ``stale_slots`` sizes the incremental cache's staled-id ring.

    ``tie_quant`` makes the greedy argmax deterministic across
    backends: marginal gains are quantized to ``tie_quant`` × max|gain|
    before the argmax, so floating-point ulp noise (which differs
    between the host loop's per-round XLA programs and the fused
    scan/sweep programs) cannot flip near-ties — and exact ties break
    lexicographically toward the smallest client id (``argmax`` returns
    the first maximum).  ``tie_quant=0`` restores raw-gain argmax.
    """
    n = int(num_clients)
    k = min(int(num_select), n)
    if refresh not in ("all", "selected"):
        raise ValueError(f"refresh must be 'all' or 'selected', "
                         f"got {refresh!r}")
    selected_only = refresh == "selected"
    project, feat_width = _make_projector(proj_dim, int(proj_seed))
    f_dim = max(1, feat_width(int(feat_dim)))
    incremental = bool(incremental) and selected_only
    stale_len = k * max(1, int(stale_slots))
    tie_quant = float(tie_quant)

    def init(key):
        return init_state(key, n, weights, feat_dim=f_dim,
                          dist_cache=incremental,
                          stale_len=stale_len if incremental else 0)

    def select(state, t, key=None):
        state, key = take_key(state, key)

        if incremental:
            def _refresh(_):
                return cached_feature_step(
                    state.feats, state.dist_cache, state.row_stats,
                    state.stale_ids, metric="l2")

            dist_c, stats_c = jax.lax.cond(
                state.stale_fill > 0, _refresh,
                lambda _: (state.dist_cache, state.row_stats), 0)
            state = stale_clear(state._replace(
                dist_cache=dist_c, row_stats=stats_c))

        def cold(key):
            if selected_only:
                # poll everyone once before trusting the distances
                return coverage_sweep_device(key, state.seen, k)
            return weighted_sample_device(key, state.weights, k)

        def warm(key):
            if incremental:
                dist = state.dist_cache
            else:
                g = state.feats
                sq = jnp.sum(g * g, axis=1)
                dist = jnp.sqrt(jnp.clip(
                    sq[:, None] + sq[None, :] - 2.0 * (g @ g.T), 0.0,
                    None))

            # greedy facility location: minimize Σ_i min_{j∈S} dist(i,j)
            def body(i, carry):
                chosen, taken, cover = carry
                gains = jnp.sum(jnp.maximum(cover[None, :] - dist, 0.0),
                                axis=1)
                if tie_quant > 0.0:
                    # quantize so ulp noise can't flip near-ties; exact
                    # ties then break toward the smallest client id
                    scale = jnp.maximum(jnp.max(jnp.abs(gains)),
                                        _LOG_FLOOR) * tie_quant
                    gains = jnp.round(gains / scale)
                j = jnp.argmax(jnp.where(taken, -jnp.inf, gains))
                return (chosen.at[i].set(j.astype(jnp.int32)),
                        taken.at[j].set(True),
                        jnp.minimum(cover, dist[j]))

            chosen, _, _ = jax.lax.fori_loop(
                0, k, body, (jnp.zeros(k, jnp.int32),
                             jnp.zeros(n, bool), jnp.full(n, jnp.inf)))
            return chosen

        warm_ok = (state.unseen_count == 0 if selected_only
                   else state.hist_count > 0)
        ids = jax.lax.cond(warm_ok, warm, cold, key)
        return ids, state

    def update(state, t, ids, obs):
        if obs.full_updates is None:
            return state
        if selected_only:
            # practical setting: participants' rows only (gather before
            # project — hashing all N |θ|-wide rows to keep K is waste)
            raw = jnp.asarray(obs.full_updates, jnp.float32)
            rows = project(raw[ids] if raw.shape[0] == n else raw)
            state = mark_seen(state._replace(
                feats=state.feats.at[ids].set(rows),
                hist_count=state.hist_count + 1), ids)
            if incremental:
                state = stale_append(state, ids)
            return state
        # ideal setting: only a full (N, P) poll refreshes the buffer
        if obs.full_updates.shape[0] != n:
            return state
        return state._replace(
            feats=project(jnp.asarray(obs.full_updates, jnp.float32)),
            hist_count=state.hist_count + 1)

    requires = frozenset({"full_sel" if selected_only else "full_all"})
    return FunctionalSelector("divfl", requires, init, select, update,
                              jit_capable=True, feat_width=feat_width)


# ---------------------------------------------------------------------------
# fedcor
# ---------------------------------------------------------------------------


def fedcor_functional(num_clients: int, num_select: int, total_rounds: int,
                      weights=None, warmup: int = 10, beta: float = 0.9,
                      length_scale: float = 1.0, hist_len: int = 8,
                      **_kw) -> FunctionalSelector:
    n = int(num_clients)
    k = min(int(num_select), n)
    warmup, beta, ls = int(warmup), float(beta), float(length_scale)
    h_len = int(hist_len)

    def init(key):
        return init_state(key, n, weights, hist_len=h_len)

    def select(state, t, key=None):
        state, key = take_key(state, key)

        def cold(key):
            return weighted_sample_device(key, state.weights, k)

        def warm(key):
            # standardized loss-history embedding over the valid ring
            x = state.loss_hist.T                      # (N, H), newest last
            valid = (jnp.arange(h_len)
                     >= h_len - jnp.minimum(state.hist_count, h_len))
            cnt = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
            mu = jnp.sum(x * valid, axis=1, keepdims=True) / cnt
            var = jnp.sum(jnp.square((x - mu) * valid), axis=1,
                          keepdims=True) / cnt
            xs = (x - mu) / (jnp.sqrt(var) + 1e-8) * valid
            d2 = jnp.sum(jnp.square(xs[:, None, :] - xs[None, :, :]), -1)
            kmat = jnp.exp(-d2 / (2.0 * ls * ls))
            w_t = jnp.power(beta, jnp.maximum(t - warmup, 0))
            kmat = w_t * kmat + (1.0 - w_t) * jnp.eye(n)

            # greedy max variance-reduction weighted by current losses
            def body(i, carry):
                chosen, taken, var_d, cov = carry
                score = jnp.where(taken, -jnp.inf,
                                  var_d * (1.0 + state.losses))
                j = jnp.argmax(score)
                cj = cov[:, j]
                denom = cov[j, j] + 1e-8
                return (chosen.at[i].set(j.astype(jnp.int32)),
                        taken.at[j].set(True),
                        var_d - cj * cj / denom,
                        cov - jnp.outer(cj, cj) / denom)

            chosen, _, _, _ = jax.lax.fori_loop(
                0, k, body, (jnp.zeros(k, jnp.int32), jnp.zeros(n, bool),
                             jnp.diagonal(kmat), kmat))
            return chosen

        ids = jax.lax.cond((t >= warmup) & (state.hist_count >= 2),
                           warm, cold, key)
        return ids, state

    def update(state, t, ids, obs):
        if obs.losses is None:
            return state
        losses = jnp.asarray(obs.losses, jnp.float32)
        hist = jnp.roll(state.loss_hist, -1, axis=0).at[-1].set(losses)
        return state._replace(losses=losses, loss_hist=hist,
                              hist_count=state.hist_count + 1)

    return FunctionalSelector("fedcor", frozenset({"loss_all"}), init,
                              select, update)


# ---------------------------------------------------------------------------
# OO shims
# ---------------------------------------------------------------------------


class RandomSelector(ClientSelector):
    """FedProx-style multinomial sampling ∝ p_k, without replacement."""
    name = "random"
    requires = frozenset()

    def _make_functional(self, **kw):
        return random_functional(**kw)


class PowerOfChoiceSelector(ClientSelector):
    """pow-d [8], ideal setting (App. A.1.2): d = N — the server asks
    *all* clients for their current local loss each round."""
    name = "pow-d"
    requires = frozenset({"loss_all"})

    def _make_functional(self, **kw):
        return powd_functional(**kw)


class ClusteredSamplingSelector(ClientSelector):
    """Clustered Sampling [11] (Alg. 2 flavour) on *full* updates —
    the O(N²|θ|) similarity cost Table 3 charges it with.  The K-row
    distance cache (``incremental=True``, default) amortizes that to
    O(K·N·F) per round; ``proj_dim`` bounds F."""
    name = "cs"
    requires = frozenset({"full_sel"})

    def _make_functional(self, **kw):
        return cs_functional(**kw)


class DivFLSelector(ClientSelector):
    """DivFL [2]: greedy facility-location submodular maximization;
    ideal setting (``refresh="all"``) = 1-step gradients from all
    clients each round; ``refresh="selected"`` polls participants only
    and rides the K-row distance cache."""
    name = "divfl"
    requires = frozenset({"full_all"})

    def _make_functional(self, **kw):
        return divfl_functional(**kw)


class FedCorSelector(ClientSelector):
    """FedCor [28]: GP over running loss-history embeddings with
    annealing β; warm-up polls all clients' losses (Table 3 cost)."""
    name = "fedcor"
    requires = frozenset({"loss_all"})

    def _make_functional(self, **kw):
        return fedcor_functional(**kw)
