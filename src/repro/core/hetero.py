"""Data-heterogeneity estimation from output-layer updates (paper §3.2).

The server receives each selected client's local update of the output
layer's bias, ``Δb^(k) ∈ R^C``, and estimates the entropy of the client's
(private) label distribution as

    Ĥ(D^(k)) = H(softmax(Δb^(k) / T))                       (Eq. 7)

grounded in the expectation identity (Eq. 6, derived in App. A.3–A.4):

    E[Δb_i^(k)] = ηR (D_i^(k) Σ_c E_c − E_i)

where ``E_i = E_{(x,y)∼B^{-i}}[s_i^{-i}(x)]`` is the mean misleading
confidence of class ``i``.  Because the map D ↦ E[Δb] is affine with a
positive diagonal coefficient ``Σ_c E_c``, the tempered softmax of Δb
recovers an entropy *ordering* consistent with the true H(D) (Thm 3.3).

Everything here is O(C) per client — the paper's headline efficiency
claim (Table 3).  For LLM heads (C = vocab up to 256k) the hot paths
have Pallas TPU kernels in ``repro/kernels``; these jnp versions are the
reference implementations and the defaults on CPU.

Beyond-paper extension: modern LM heads are bias-free.  ``ΔW`` of the
head (shape (d, C) or (C, d)) satisfies the same per-class structure —
each class column's update is ``(D_i Σ E_c − E_i)·z̄``-shaped — so the
*row/column mean* of ΔW is a drop-in surrogate for Δb
(``delta_b_from_head_delta``); ROADMAP.md's open items track the
remaining estimator work.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def softmax_entropy(v: jnp.ndarray, temperature: float) -> jnp.ndarray:
    """H(softmax(v / T)) along the last axis, numerically stable.

    Uses the log-sum-exp identity  H = lnZ − Σ s·u  with u = v/T − max:
    no materialized log(p) (p can underflow to 0 for severe imbalance).
    """
    u = v / temperature
    u = u - jnp.max(u, axis=-1, keepdims=True)
    z = jnp.sum(jnp.exp(u), axis=-1)
    s = jnp.sum(jnp.exp(u) * u, axis=-1)
    return jnp.log(z) - s / z


def estimate_entropy(delta_b: jnp.ndarray, temperature: float,
                     normalize: bool = False) -> jnp.ndarray:
    """Ĥ(D) per Eq. 7.  delta_b: (..., C) bias update(s).

    ``normalize=True`` is a beyond-paper robustness extension: Δb is
    RMS-normalized per client before the tempered softmax, making Ĥ
    invariant to BOTH the per-round update magnitude (lr decay, training
    progress, per-client ηR) and the class count C (RMS rather than L2,
    so elements stay O(1) whether C=10 or C=256k and one temperature
    works across heads).  The paper's fixed-T estimator implicitly
    assumes comparable magnitudes; in our experiments the normalized
    variant raises corr(Ĥ, H_true) from ≈0.4 to ≈0.86 when Δb's are
    collected across many rounds (reproduce with
    ``benchmarks/bench_estimation.py``).
    """
    if normalize:
        rms = jnp.sqrt(jnp.mean(jnp.square(delta_b), axis=-1,
                                keepdims=True))
        delta_b = delta_b / jnp.clip(rms, 1e-12, None)
    return softmax_entropy(delta_b, temperature)


def label_entropy(dist: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """True Shannon entropy H(D) of label distribution(s) (..., C)."""
    p = dist / jnp.clip(jnp.sum(dist, -1, keepdims=True), eps, None)
    return -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.clip(p, eps, None)), 0.0),
                    axis=-1)


def expected_bias_update(dist: jnp.ndarray, e_vec: jnp.ndarray,
                         eta: float, epochs: int) -> jnp.ndarray:
    """Eq. 6 forward model:  E[Δb_i] = ηR (D_i Σ_c E_c − E_i).

    dist: (..., C) label distribution; e_vec: (C,) misleading-confidence
    vector E.  Used by tests/benchmarks to validate the estimator against
    its own theory and to build synthetic Δb with known ground truth.
    """
    return eta * epochs * (dist * jnp.sum(e_vec, -1, keepdims=True) - e_vec)


def delta_b_from_head_delta(delta_w: jnp.ndarray,
                            class_axis: int = -1) -> jnp.ndarray:
    """Bias-free-head surrogate: mean of ΔW over the feature axis.

    delta_w: head-weight update with one class axis (size C) and one
    feature axis (size d).  Returns a (C,) pseudo-Δb.  By the same
    derivation as Eq. 6 with z in place of the constant 1, the feature-
    mean of each class's weight-update row is ηR (D_i Σ E_c − E_i)·mean(z̄)
    — same affine structure, same ordering.
    """
    if delta_w.ndim != 2:
        raise ValueError(f"head delta must be 2-D, got {delta_w.shape}")
    feat_axis = 0 if class_axis in (-1, 1) else 1
    return jnp.mean(delta_w, axis=feat_axis)


def head_bias_update(params_before, params_after,
                     bias_path: str = "lm_head/b") -> Optional[jnp.ndarray]:
    """Extract Δb (or the ΔW surrogate) from two param pytrees.

    Prefers the real bias at ``bias_path``; falls back to the weight at
    ``lm_head/w`` via :func:`delta_b_from_head_delta` when the head is
    bias-free.  Returns None when the model has no recognizable head.
    """
    flat_b = dict(_flatten(params_before))
    flat_a = dict(_flatten(params_after))
    if bias_path in flat_b:
        return flat_a[bias_path] - flat_b[bias_path]
    wpath = bias_path.rsplit("/", 1)[0] + "/w"
    if wpath in flat_b:
        return delta_b_from_head_delta(flat_a[wpath] - flat_b[wpath])
    return None


def head_bias_updates_stacked(params_before, stacked_after,
                              bias_path: str = "lm_head/b"
                              ) -> Optional[jnp.ndarray]:
    """Cohort-vectorized Δb extraction: (global params, K-stacked local
    params) -> (K, C), with no per-client Python loop.

    ``stacked_after`` is the vmapped LocalUpdate output (every leaf has
    a leading K axis).  Same head resolution as
    :func:`head_bias_update`: real bias at ``bias_path`` first, else
    the feature-mean ΔW surrogate at ``lm_head/w``; None when the model
    has no recognizable head.
    """
    flat_b = dict(_flatten(params_before))
    flat_a = dict(_flatten(stacked_after))
    if bias_path in flat_b:
        return flat_a[bias_path] - flat_b[bias_path][None]
    wpath = bias_path.rsplit("/", 1)[0] + "/w"
    if wpath in flat_b:
        # (K, d, C) — per-class mean over the feature axis, matching
        # delta_b_from_head_delta(class_axis=-1) per client
        dw = flat_a[wpath] - flat_b[wpath][None]
        return jnp.mean(dw, axis=1)
    return None


def head_num_classes(params, bias_path: str = "lm_head/b") -> Optional[int]:
    """Class-axis width C the head's Δb (or ΔW surrogate) will have —
    lets the server size the selector's device-resident Δb buffer at
    init instead of on first observation.  None when the model has no
    recognizable head."""
    flat = dict(_flatten(params))
    if bias_path in flat:
        return int(flat[bias_path].shape[-1])
    wpath = bias_path.rsplit("/", 1)[0] + "/w"
    if wpath in flat:
        return int(flat[wpath].shape[-1])
    return None


def _flatten(tree):
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        out.append(("/".join(parts), leaf))
    return out


# ---------------------------------------------------------------------------
# Theory-facing helpers (Assumption 3.1 / Thm 3.3 validation)
# ---------------------------------------------------------------------------


def dissimilarity_envelope(h: np.ndarray, kappa: float, rho: float,
                           beta: float, h0: Optional[float] = None,
                           num_classes: int = 10) -> np.ndarray:
    """σ_k² = κ − ρ e^{β (H − H(D₀))}: Assumption 3.1's envelope curve."""
    if h0 is None:
        h0 = float(np.log(num_classes))
    return kappa - rho * np.exp(beta * (np.asarray(h) - h0))


def entropy_separation_bound(dist_k: np.ndarray, dist_u: np.ndarray,
                             e_sum: float, delta: float, eta: float,
                             epochs: int, temperature: float) -> float:
    """Right-hand side of Thm 3.3 (Eq. 8) for a client pair (u balanced,
    k imbalanced).  Positive ⇒ the theorem predicts Ĥ(u) > Ĥ(k) in
    expectation."""
    C = dist_k.shape[-1]
    u = np.full(C, 1.0 / C)
    t1 = 0.5 * (eta * epochs * e_sum / (C * temperature)) ** 2 \
        * float(np.sum((dist_k - u) ** 2))
    t2 = eta * epochs / temperature * float(np.max(np.abs(dist_u - u)))
    cc = eta * epochs * (eta * epochs + C * C * temperature * np.log(C)) \
        / (C * C * temperature * temperature)
    return t1 - t2 - cc * delta
