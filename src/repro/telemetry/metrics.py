"""Device-resident metric groups: the in-scan ``Telemetry`` pytree.

A :class:`MetricsSpec` names which metric *groups* a run records;
:func:`make_metrics` compiles that choice into a pure
``(init, step)`` pair the drivers thread through their jitted round
bodies:

    telc            = metrics.init()                # scan-carry pytree
    telc, telemetry = metrics.step(telc, ctx)       # inside round_step

``telemetry`` is a flat ``{"group/field": array}`` dict — an ordinary
scan output, so ``lax.scan`` stacks it to ``(T, ...)`` per field and
``vmap`` batches it over the sweep's seed axis with zero host
callbacks.  The carry holds the few metrics that accumulate across
rounds (the fairness times-selected histogram).

Schema contract: the field SET is identical for every group
combination — disabled groups (and fields whose inputs a driver cannot
supply, e.g. ``async/*`` on the sync loop) materialize zero-width
``(0,)`` arrays, exactly like ``SelectorState.stale_ids`` does for
non-incremental selectors.  Enabling a group therefore never changes
the pytree *structure*, only array widths, and the training
computation is untouched: every metric is derived from values the
round body already produced, so telemetry-on and telemetry-off runs
take bit-identical trajectories (pinned by tests/test_telemetry.py).

Groups:

  selection — Ĥ-estimate health: mean/std spread, cohort mean,
              Ĥ-vs-true-partition-entropy MAE + Spearman rank
              correlation (the Eq. 9 estimation-quality observable;
              needs ``ctx.true_entropy``), distance-cache staleness
              fill, and — when the selector exposes ``diagnostics`` —
              cluster sizes and within-cluster Ĥ spread.
  training  — per-round train loss, mean ‖Δb‖ row norm, global update
              norm ‖θ^{t+1} − θ^t‖, lr scale.
  fairness  — cumulative times-selected histogram, participation rate
              (fraction ever selected), effective participation
              exp(H(counts))/N.
  async     — buffer fill, accepted/overflow-dropped counts,
              aggregation trigger, server version, version lag of the
              oldest buffered entry, staleness ages of the aggregated
              cohort (−1-padded when the tick didn't fire).

Imports from ``repro.core`` are deliberately lazy (inside functions):
``repro.kernels`` pulls in :mod:`repro.telemetry.trace` at import
time, so a module-level ``repro.core`` import here would close an
import cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

#: every registered metric group, in schema order.
GROUPS: Tuple[str, ...] = ("selection", "training", "fairness", "async")


@dataclasses.dataclass(frozen=True)
class MetricsSpec:
    """Which metric groups a run records.  ``groups=()`` is telemetry
    off: every field in the schema is emitted zero-width."""
    groups: Tuple[str, ...] = ()

    def __post_init__(self):
        unknown = set(self.groups) - set(GROUPS)
        if unknown:
            raise ValueError(f"unknown metric groups {sorted(unknown)}; "
                             f"known: {list(GROUPS)}")
        object.__setattr__(self, "groups", tuple(self.groups))

    def enabled(self, group: str) -> bool:
        return group in self.groups

    @classmethod
    def all(cls) -> "MetricsSpec":
        return cls(groups=GROUPS)


class TelemetryCtx(NamedTuple):
    """What the round/tick body hands the metrics step.  Every field a
    driver cannot supply stays ``None`` — the corresponding metrics
    come out zero-width (the decision is static per trace, so the scan
    still compiles once)."""
    t: Any = None                    # round / tick index
    ids: Any = None                  # (K,) dispatched cohort
    state: Any = None                # post-update SelectorState
    train_loss: Any = None           # () cohort mean train loss
    true_entropy: Any = None         # (N,) H(D_k) of the true partition
    params_before: Any = None        # θ^t   (pre-aggregation)
    params_after: Any = None         # θ^{t+1}
    bias_updates: Any = None         # (K, C) cohort Δb
    lr_scale: Any = None             # () decay factor
    # -- async tick extras ------------------------------------------------
    fired: Any = None                # () bool — aggregation triggered
    fill: Any = None                 # () buffer fill after the tick
    accepted: Any = None             # () arrivals buffered this tick
    dropped: Any = None              # () arrivals overflow-dropped
    version: Any = None              # () server version after the tick
    version_lag: Any = None          # () version − oldest buffered
    agg_ages: Any = None             # (M,) popped ages, −1 when idle


class Metrics(NamedTuple):
    """The compiled ``(init, step)`` pair plus its spec."""
    spec: MetricsSpec
    init: Callable[[], Dict[str, jnp.ndarray]]
    step: Callable[..., tuple]   # (carry, ctx) -> (carry, telemetry)


def _zf() -> jnp.ndarray:
    return jnp.zeros((0,), jnp.float32)


def _zi() -> jnp.ndarray:
    return jnp.zeros((0,), jnp.int32)


def _f32(v) -> jnp.ndarray:
    return jnp.asarray(v, jnp.float32)


def _flat_norm_sq(a, b) -> jnp.ndarray:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(jnp.sum(jnp.square(_f32(x) - _f32(y)))
               for x, y in zip(leaves_a, leaves_b))


def _ranks(v: jnp.ndarray) -> jnp.ndarray:
    order = jnp.argsort(v)
    return jnp.zeros(v.shape, jnp.float32).at[order].set(
        jnp.arange(v.shape[0], dtype=jnp.float32))


def spearman(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Spearman rank correlation of two (N,) vectors (ties broken by
    argsort order — Ĥ ties are measure-zero for real updates).  The
    ordering-consistency observable Thm 3.3 actually promises, unlike
    a raw value comparison."""
    ra, rb = _ranks(a), _ranks(b)
    ra = ra - jnp.mean(ra)
    rb = rb - jnp.mean(rb)
    denom = jnp.sqrt(jnp.sum(ra * ra) * jnp.sum(rb * rb))
    return jnp.where(denom > 0, jnp.sum(ra * rb) / denom, 0.0)


def client_true_entropy(y, mask, num_classes: int) -> jnp.ndarray:
    """(N,) true label entropy H(D_k) from padded labels + sample mask
    — the ground truth the ``selection`` group scores Ĥ against
    (Wang et al.'s Ĥ-vs-true-distribution comparison, per round).
    Pure device ops, so the sweep engine computes it per seed inside
    the vmapped runner."""
    from repro.core.hetero import label_entropy
    onehot = jax.nn.one_hot(jnp.asarray(y, jnp.int32),
                            int(num_classes)) \
        * _f32(mask)[..., None]
    return label_entropy(onehot.sum(axis=-2))


def make_metrics(spec: MetricsSpec, fn=None, num_clients: int = 0,
                 num_select: int = 0) -> Metrics:
    """Compile a :class:`MetricsSpec` for one experiment shape.

    ``fn`` is the :class:`~repro.core.selectors.functional.
    FunctionalSelector` whose ``entropies`` / ``diagnostics`` hooks the
    ``selection`` group reads (optional — without it the selection
    fields are zero-width).  ``num_clients`` sizes the fairness
    histogram.
    """
    n = int(num_clients)
    want_sel = spec.enabled("selection")
    want_train = spec.enabled("training")
    want_fair = spec.enabled("fairness")
    want_async = spec.enabled("async")

    def init() -> Dict[str, jnp.ndarray]:
        return {"fairness/counts":
                jnp.zeros((n,), jnp.int32) if want_fair else _zi()}

    def step(carry: Dict[str, jnp.ndarray], ctx: TelemetryCtx):
        from repro.core.selectors.functional import state_entropies
        out: Dict[str, jnp.ndarray] = {}

        # -- selection ----------------------------------------------------
        ent = (state_entropies(fn, ctx.state)
               if want_sel and fn is not None and ctx.state is not None
               else _zf())
        have_ent = ent.shape[0] > 0
        if have_ent:
            out["selection/ent_mean"] = jnp.mean(ent)
            out["selection/ent_std"] = jnp.std(ent)
            out["selection/ent_selected_mean"] = (
                jnp.mean(ent[ctx.ids]) if ctx.ids is not None
                else jnp.mean(ent))
        else:
            out["selection/ent_mean"] = _zf()
            out["selection/ent_std"] = _zf()
            out["selection/ent_selected_mean"] = _zf()
        if have_ent and ctx.true_entropy is not None:
            te = _f32(ctx.true_entropy)
            out["selection/ent_mae"] = jnp.mean(jnp.abs(ent - te))
            out["selection/ent_rank_corr"] = spearman(ent, te)
        else:
            out["selection/ent_mae"] = _zf()
            out["selection/ent_rank_corr"] = _zf()
        ring = (int(ctx.state.stale_ids.shape[0])
                if want_sel and ctx.state is not None else 0)
        out["selection/stale_frac"] = (
            _f32(ctx.state.stale_fill) / ring if ring else _zf())
        if want_sel and fn is not None and fn.diagnostics is not None \
                and ctx.state is not None:
            diag = fn.diagnostics(ctx.state)
            out["selection/cluster_sizes"] = jnp.asarray(
                diag["cluster_sizes"], jnp.int32)
            out["selection/cluster_ent_spread"] = _f32(
                diag["cluster_ent_spread"])
        else:
            out["selection/cluster_sizes"] = _zi()
            out["selection/cluster_ent_spread"] = _zf()

        # -- training -----------------------------------------------------
        out["training/loss"] = (
            _f32(ctx.train_loss)
            if want_train and ctx.train_loss is not None else _zf())
        out["training/delta_b_norm"] = (
            jnp.mean(jnp.linalg.norm(_f32(ctx.bias_updates), axis=-1))
            if want_train and ctx.bias_updates is not None else _zf())
        out["training/update_norm"] = (
            jnp.sqrt(_flat_norm_sq(ctx.params_after, ctx.params_before))
            if want_train and ctx.params_before is not None
            and ctx.params_after is not None else _zf())
        out["training/lr_scale"] = (
            _f32(ctx.lr_scale)
            if want_train and ctx.lr_scale is not None else _zf())

        # -- fairness -----------------------------------------------------
        counts = carry["fairness/counts"]
        if want_fair and ctx.ids is not None:
            counts = counts.at[jnp.asarray(ctx.ids, jnp.int32)].add(1)
            total = jnp.sum(counts)
            p = _f32(counts) / _f32(jnp.maximum(total, 1))
            hp = -jnp.sum(jnp.where(
                counts > 0, p * jnp.log(jnp.clip(p, 1e-12, None)), 0.0))
            out["fairness/sel_counts"] = counts
            out["fairness/participation"] = jnp.mean(
                (counts > 0).astype(jnp.float32))
            out["fairness/eff_participation"] = jnp.where(
                total > 0, jnp.exp(hp) / max(1, n), 0.0)
        else:
            out["fairness/sel_counts"] = _zi()
            out["fairness/participation"] = _zf()
            out["fairness/eff_participation"] = _zf()

        # -- async --------------------------------------------------------
        for field, val in (("fired", ctx.fired), ("fill", ctx.fill),
                           ("accepted", ctx.accepted),
                           ("dropped", ctx.dropped),
                           ("version", ctx.version),
                           ("version_lag", ctx.version_lag)):
            out[f"async/{field}"] = (
                _f32(val) if want_async and val is not None else _zf())
        out["async/agg_ages"] = (
            _f32(ctx.agg_ages)
            if want_async and ctx.agg_ages is not None else _zf())

        return {"fairness/counts": counts}, out

    return Metrics(spec, init, step)
