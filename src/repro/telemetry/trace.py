"""Profiler trace hooks behind the ``REPRO_TRACE=1`` env switch.

``jax.profiler.trace`` dumps are flat without annotations: every scan
segment and kernel dispatch is an anonymous XLA program.  These two
wrappers label the repo's subsystems —

  * :func:`annotate` decorates a function so its execution shows up as
    a named span (``jax.profiler.annotate_function``); the Pallas
    kernel entry points in ``repro.kernels.ops`` are wrapped with
    ``kernels/<name>`` labels.
  * :func:`trace_span` is the context-manager form
    (``jax.profiler.TraceAnnotation``); the sync scanned loop, the
    async tick scan and the sweep engine wrap their device dispatches
    in ``fed/...`` / ``sweep/...`` spans.

Both are exact no-ops unless ``REPRO_TRACE=1`` is set in the
environment at import time, so the hot paths carry zero overhead by
default and the traced program is byte-identical either way (an
annotation names a span; it does not change what XLA compiles).

Usage::

    REPRO_TRACE=1 python - <<'PY'
    import jax
    with jax.profiler.trace("/tmp/trace"):
        ...   # spans now carry kernels/... and fed/... labels
    PY

This module deliberately imports nothing from the rest of the repo:
``repro.kernels`` wraps its entry points with it, and the package
``__init__`` chain must stay cycle-free.
"""
from __future__ import annotations

import contextlib
import os
from typing import Callable

_ENABLED = os.environ.get("REPRO_TRACE", "") == "1"


def trace_enabled() -> bool:
    """Whether ``REPRO_TRACE=1`` was set when the process started."""
    return _ENABLED


def annotate(name: str) -> Callable:
    """Decorator: label a function as a profiler span (no-op unless
    ``REPRO_TRACE=1``)."""
    def deco(fn: Callable) -> Callable:
        if not _ENABLED:
            return fn
        import jax.profiler
        return jax.profiler.annotate_function(fn, name=name)
    return deco


def trace_span(name: str):
    """Context manager: label a code region as a profiler span (no-op
    unless ``REPRO_TRACE=1``)."""
    if not _ENABLED:
        return contextlib.nullcontext()
    import jax.profiler
    return jax.profiler.TraceAnnotation(name)
