"""Host-side telemetry export: JSONL runs, summaries, env stamps.

The device side (:mod:`repro.telemetry.metrics`) hands back a flat
``{"group/field": array}`` dict with a leading time axis — ``(T, ...)``
from a single run, ``(S, T, ...)`` from the sweep engine's seed-vmapped
runner.  This module is the one host transfer at the end of a run:

  * :func:`write_run` / :func:`write_sweep` — flatten to JSONL, one
    record per round (per seed/cell for sweeps), preceded by a header
    record carrying the env stamp, user metadata and field list.
    Zero-width fields (disabled groups) are simply absent from the
    records, so a reader never confuses "off" with "measured 0".
  * :func:`read_jsonl` / :func:`telemetry_from_records` — the inverse,
    used by the schema round-trip test and by ad-hoc analysis.
  * :func:`summarize` — compact ``{field: {last, mean, min, max}}``
    digest for logs and benchmark payloads.
  * :func:`env_stamp` — jax version, backend/device kind, CPU count,
    git SHA.  ``benchmarks/common.save_result`` stamps it into every
    ``BENCH_*.json`` so ``tools/bench_gate.py`` can refuse
    cross-machine comparisons instead of flagging them as regressions.

Everything here is plain-Python/numpy; nothing is called from inside a
jitted program.
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# environment stamp
# ---------------------------------------------------------------------------

def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


def env_stamp() -> Dict[str, Any]:
    """Machine/runtime identity for benchmark artifacts.

    The comparison key for the bench gate is the subset that changes
    perf characteristics: backend, device kind and CPU count.  The rest
    (versions, SHA) is provenance.
    """
    import jax
    devs = jax.devices()
    return {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "unknown",
        "device_count": len(devs),
        "cpu_count": os.cpu_count(),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "git_sha": _git_sha(),
    }


#: env-stamp keys that must match for a benchmark comparison to be fair.
COMPARE_KEYS = ("backend", "device_kind", "cpu_count")


def env_comparable(a: Dict[str, Any], b: Dict[str, Any]) -> bool:
    """Whether two env stamps came from perf-equivalent machines."""
    return all(a.get(k) == b.get(k) for k in COMPARE_KEYS)


# ---------------------------------------------------------------------------
# telemetry -> records
# ---------------------------------------------------------------------------

def _live_fields(telemetry: Dict[str, Any]) -> List[str]:
    """Field names whose trailing width is non-zero (enabled groups)."""
    out = []
    for name in sorted(telemetry):
        arr = np.asarray(telemetry[name])
        if arr.ndim == 0 or 0 not in arr.shape:
            out.append(name)
    return out


def _jsonify(v: np.ndarray):
    arr = np.asarray(v)
    if arr.ndim == 0:
        return arr.item()
    return arr.tolist()


def records_from_telemetry(telemetry: Dict[str, Any],
                           extra: Optional[Dict[str, Any]] = None,
                           ) -> List[Dict[str, Any]]:
    """One JSON-ready record per round from a ``(T, ...)``-stacked
    telemetry dict.  Zero-width fields are dropped; ``extra`` keys
    (e.g. ``{"seed": 3, "cell": "hics"}``) are merged into every
    record."""
    fields = _live_fields(telemetry)
    if not fields:
        return []
    arrays = {k: np.asarray(telemetry[k]) for k in fields}
    steps = {a.shape[0] for a in arrays.values()}
    if len(steps) != 1:
        raise ValueError(f"inconsistent time axes across fields: {steps}")
    (T,) = steps
    records = []
    for t in range(T):
        rec: Dict[str, Any] = {"kind": "round", "t": t}
        if extra:
            rec.update(extra)
        for k in fields:
            rec[k] = _jsonify(arrays[k][t])
        records.append(rec)
    return records


def telemetry_from_records(records: Iterable[Dict[str, Any]],
                           ) -> Dict[str, np.ndarray]:
    """Inverse of :func:`records_from_telemetry` for a single run:
    stacks round records back into ``{field: (T, ...) ndarray}``."""
    rounds = sorted((r for r in records if r.get("kind") == "round"),
                    key=lambda r: r["t"])
    if not rounds:
        return {}
    fields = [k for k in rounds[0] if "/" in k]
    return {k: np.asarray([r[k] for r in rounds]) for k in fields}


def summarize(telemetry: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Compact per-field digest: last/mean/min/max over the time axis
    (vector fields summarize their final row).  Zero-width fields are
    omitted."""
    out: Dict[str, Dict[str, Any]] = {}
    for name in _live_fields(telemetry):
        arr = np.asarray(telemetry[name], dtype=np.float64)
        if arr.ndim == 1:
            out[name] = {"last": float(arr[-1]), "mean": float(arr.mean()),
                         "min": float(arr.min()), "max": float(arr.max())}
        else:
            last = arr[-1]
            out[name] = {"last": last.tolist(),
                         "mean": float(arr.mean())}
    return out


# ---------------------------------------------------------------------------
# JSONL I/O
# ---------------------------------------------------------------------------

def write_jsonl(path, records: Iterable[Dict[str, Any]]) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as fh:
        for rec in records:
            fh.write(json.dumps(rec) + "\n")


def read_jsonl(path) -> List[Dict[str, Any]]:
    with Path(path).open() as fh:
        return [json.loads(line) for line in fh if line.strip()]


def _header(telemetry_fields: List[str],
            meta: Optional[Dict[str, Any]]) -> Dict[str, Any]:
    return {"kind": "header", "env": env_stamp(),
            "meta": dict(meta or {}), "fields": telemetry_fields}


def write_run(path, telemetry: Dict[str, Any],
              meta: Optional[Dict[str, Any]] = None,
              ) -> Dict[str, Dict[str, Any]]:
    """Write one run's telemetry as JSONL (header + per-round records)
    and return its :func:`summarize` digest."""
    records = [_header(_live_fields(telemetry), meta)]
    records += records_from_telemetry(telemetry)
    write_jsonl(path, records)
    return summarize(telemetry)


def write_sweep(path, cells: Dict[str, Dict[str, Any]],
                meta: Optional[Dict[str, Any]] = None,
                ) -> Dict[str, Dict[str, Dict[str, Any]]]:
    """Write sweep telemetry as JSONL.

    ``cells`` maps a cell name (e.g. ``"pathological/hics"``) to a
    telemetry dict whose fields carry a leading *seed* axis:
    ``(S, T, ...)``.  Each (cell, seed) pair becomes a run of round
    records tagged ``{"cell": ..., "seed": ...}``.  Returns
    ``{cell: summary-of-seed-mean}``.
    """
    all_fields = sorted({f for tel in cells.values()
                         for f in _live_fields(tel)})
    records: List[Dict[str, Any]] = [_header(all_fields, meta)]
    summaries: Dict[str, Dict[str, Dict[str, Any]]] = {}
    for cell, tel in cells.items():
        live = _live_fields(tel)
        n_seeds = {np.asarray(tel[f]).shape[0] for f in live}
        if len(n_seeds) > 1:
            raise ValueError(f"inconsistent seed axes in cell {cell!r}: "
                             f"{n_seeds}")
        for s in range(next(iter(n_seeds), 0)):
            per_seed = {f: np.asarray(tel[f])[s] for f in live}
            records += records_from_telemetry(
                per_seed, extra={"cell": cell, "seed": s})
        seed_mean = {f: np.asarray(tel[f], dtype=np.float64).mean(axis=0)
                     for f in live}
        summaries[cell] = summarize(seed_mean)
    write_jsonl(path, records)
    return summaries
