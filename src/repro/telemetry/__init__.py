"""Device-resident telemetry: in-scan metrics pytrees, host-side JSONL
export, and profiler trace hooks.

Three pieces, one discipline (nothing leaves the device mid-scan):

  metrics.py — a :class:`MetricsSpec` registry of metric *groups*
               (``selection`` / ``training`` / ``fairness`` /
               ``async``); each enabled group contributes fields to a
               flat ``Telemetry`` dict pytree emitted as an extra
               ``lax.scan`` output by all three drivers (sync scanned
               loop, async tick scan, vmapped sweep).  Disabled groups
               materialize zero-width arrays — same pytree structure,
               no second code path, no re-jits.
  export.py — flattens stacked telemetry to JSONL + a summary dict,
               and stamps environment metadata (jax version, backend,
               git SHA) into benchmark artifacts so the bench gate can
               refuse cross-machine comparisons.
  trace.py  — ``jax.profiler`` span annotations behind the
               ``REPRO_TRACE=1`` env switch; the Pallas kernel call
               sites and the drivers' scan segments are wrapped, so
               ``jax.profiler.trace`` dumps are labeled by subsystem.

See docs/observability.md for the full tour.
"""
from repro.telemetry.export import (env_stamp, read_jsonl, records_from_telemetry,
                                    summarize, telemetry_from_records,
                                    write_jsonl, write_run, write_sweep)
from repro.telemetry.metrics import (GROUPS, Metrics, MetricsSpec,
                                     TelemetryCtx, client_true_entropy,
                                     make_metrics)
from repro.telemetry.trace import annotate, trace_enabled, trace_span

__all__ = [
    "GROUPS", "Metrics", "MetricsSpec", "TelemetryCtx",
    "client_true_entropy", "make_metrics",
    "env_stamp", "read_jsonl", "records_from_telemetry", "summarize",
    "telemetry_from_records", "write_jsonl", "write_run", "write_sweep",
    "annotate", "trace_enabled", "trace_span",
]
