"""The vmapped multi-seed / multi-scenario sweep engine.

One federated experiment is a pytree: model params, a
``SelectorState``, a fixed-capacity :class:`~repro.scenarios.
partition_jax.Partition`, and a per-round PRNG-key chain.  This module
stacks that pytree over seeds and drives the SAME jitted round step the
server scans — ``jax.vmap`` turns "run S seeds" into one XLA program
whose cohort updates batch across seeds on the MXU, instead of S
sequential Python loops.  Selector-side caches (incremental HiCS's
(S, N, N) stacked distance cache included) are ordinary state-pytree
leaves, so they batch over the seed axis with everything else.

Parity contract (asserted in tests/test_sweep.py): for a fixed seed the
engine reproduces ``FederatedServer``'s host loop exactly — same
params-init / round-key / selector-key chains, same op order inside the
round (select → vmapped local update → aggregate → stacked Δb →
selector update), and client batches gathered through the partition's
index tensor equal the server's materialized ``x[idx]`` arrays.  So
per-seed participant sets are identical and accuracies match to f32
tolerance, whether seeds run vmapped, serially through the engine, or
serially through the server.

Three drivers, one round step:

  run_sweep(spec)            scenarios × selectors grid, seeds vmapped;
                             mean±std accuracy / entropy trajectories
  run_host_reference(...)    one (scenario, selector, seed) through the
                             FederatedServer host loop on the same data
  bench_sweep(spec)          vmapped vs python-seed-loop wall time →
                             the BENCH_sweep.json payload
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (SELECTORS, Observations, head_bias_updates_stacked,
                        head_num_classes, make_functional)
from repro.data import SyntheticSpec
from repro.fed.async_server import (_ASYNC_SCANNABLE, AsyncConfig,
                                    make_tick_step)
from repro.fed.client import (LocalSpec, init_extra, make_eval_fn,
                              make_local_update)
from repro.fed.latency import delay_tables, max_delay
from repro.fed.server import (_SCANNABLE, FedConfig, FederatedServer,
                              _tree_stack_gather, _tree_stack_scatter,
                              aggregate_params, full_sel_updates,
                              make_grad_all)
from repro.models.classifier import (make_classifier,
                                     make_classifier_with_features)
from repro.scenarios.availability import availability_mask, masked_select
from repro.scenarios.partition_jax import Partition
from repro.core.selectors.functional import state_entropies
from repro.scenarios.registry import (Scenario, get_scenario, make_dataset,
                                      materialize, scenario_key)
from repro.telemetry import (MetricsSpec, TelemetryCtx, client_true_entropy,
                             env_stamp, make_metrics, trace_span)

#: the sweep runs the server's scanned round body, so it can satisfy
#: exactly the requirements that body can (one source of truth)
_SWEEPABLE = _SCANNABLE


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative sweep grid: scenarios × selectors × seeds."""
    scenarios: Sequence[str] = ("mixed_80_20", "dir_mild")
    selectors: Sequence[str] = ("hics", "random")
    seeds: Sequence[int] = (0, 1, 2, 3)
    arch: str = "paper-mlp"
    num_clients: int = 12
    num_select: int = 3
    rounds: int = 10
    cap: Optional[int] = None        # fixed per-client capacity (None →
    samples_train: int = 600         #  4·S/N, clipped to S)
    samples_test: int = 200
    selector_kw: Optional[Dict[str, Any]] = None
    local: LocalSpec = dataclasses.field(default_factory=LocalSpec)
    lr_decay_every: int = 10
    lr_decay: float = 0.5
    data_seed: int = 0
    data: Optional[SyntheticSpec] = None   # overrides every scenario's
    #: telemetry metric groups (repro.telemetry.GROUPS); () = off.  The
    #: telemetry pytree batches over the vmapped seed axis, so each
    #: cell's fields come back (S, T, ...).
    telemetry: Sequence[str] = ()

    def capacity(self) -> int:
        if self.cap is not None:
            return int(self.cap)
        return min(self.samples_train,
                   max(1, 4 * self.samples_train // self.num_clients))

    def scenario(self, name: str) -> Scenario:
        scn = get_scenario(name)
        if self.data is not None:
            scn = dataclasses.replace(scn, data=self.data)
        return scn


def seed_keychain(seed: int, rounds: int, grad_keys: bool = False):
    """Replicates ``FederatedServer`` + selector-shim key chains for one
    seed: (params-init key, selector-init key, (T, ...) round keys).

    ``grad_keys=True`` reproduces the host loop's chain for ``full_all``
    selectors (DivFL's all-clients gradient poll splits one extra key
    per round, interleaved with the round keys) and appends the (T, ...)
    grad-key array as a fourth element."""
    rng = jax.random.PRNGKey(int(seed))
    rng, k_init = jax.random.split(rng)
    round_keys, gkeys = [], []
    for _ in range(rounds):
        rng, kr = jax.random.split(rng)
        round_keys.append(kr)
        if grad_keys:
            rng, kg = jax.random.split(rng)
            gkeys.append(kg)
    _, k_sel = jax.random.split(jax.random.PRNGKey(int(seed)))
    if grad_keys:
        return k_init, k_sel, jnp.stack(round_keys), jnp.stack(gkeys)
    return k_init, k_sel, jnp.stack(round_keys)


def _normalized_weights(mask_np: np.ndarray) -> jnp.ndarray:
    """Client weights p_k ∝ |B_k| with the server/shim's exact
    normalization chain (float64 host normalize, f32 device renorm)."""
    w = mask_np.sum(axis=1).astype(np.float64)
    w = w / w.sum()
    wd = jnp.asarray(w, jnp.float32)
    return wd / jnp.sum(wd)


def _make_model(spec: SweepSpec, cfg, input_dim: int):
    """(init, apply, features) for the sweep's model — the server
    builder's exact moon special-case (the contrastive term needs the
    embedding head), so both drivers train identical models."""
    if spec.local.algo == "moon":
        return make_classifier_with_features(cfg, input_dim=input_dim)
    init_fn, apply_fn, _ = make_classifier(cfg, input_dim=input_dim)
    return init_fn, apply_fn, None


def _probe_requires(spec: SweepSpec, name: str) -> frozenset:
    """A selector's effective requirements (factory kwargs can move it
    between classes, e.g. divfl's ``refresh="selected"``), probed from
    a throwaway tiny instance — factories are pure closures, so this
    costs nothing and never touches device buffers."""
    if name not in SELECTORS:
        raise KeyError(f"unknown selector {name!r}; known: "
                       f"{sorted(SELECTORS)}")
    return make_functional(name, num_clients=2, num_select=1,
                           total_rounds=1,
                           **dict(spec.selector_kw or {})).requires


def _make_selector_fn(spec: SweepSpec, name: str, num_classes: int,
                      param_count: int):
    requires = _probe_requires(spec, name)
    unmet = requires - _SWEEPABLE
    if unmet:
        raise ValueError(
            f"sweep engine unsupported for selector {name!r} (needs "
            f"host-side {sorted(unmet)}); run it through the server loop")
    kw = dict(spec.selector_kw or {})
    if "bias_sel" in requires:
        kw.setdefault("num_classes", num_classes)
    if requires & {"full_all", "full_sel"}:
        kw.setdefault("feat_dim", param_count)
    return make_functional(name, num_clients=spec.num_clients,
                           num_select=spec.num_select,
                           total_rounds=spec.rounds, **kw)


def make_seed_runner(spec: SweepSpec, scenario: Scenario, fn, apply_fn,
                     x: jnp.ndarray, y: jnp.ndarray, test: dict,
                     features_fn=None):
    """Build ``run_seed(params0, sstate0, partition, round_keys)`` — the
    whole T-round experiment for ONE seed as a pure jit/vmap-compatible
    function.  The round body mirrors ``FederatedServer._make_round_step``
    so participant sets match the server loop key-for-key.

    Stateful local algorithms (feddyn's per-client h, moon's previous
    local params) are supported: the (N, ...) extras pytree is built
    from the seed's own ``params0`` inside ``run_seed`` — pure tree
    ops, so it batches over the vmapped seed axis like every other
    carry leaf — and gathered/scattered by participant ids each round
    exactly as the server loop does.  ``features_fn`` must be supplied
    for moon (the contrastive term embeds through it)."""
    cfg_n, cfg_k = spec.num_clients, spec.num_select
    has_extras = spec.local.algo in ("feddyn", "moon")
    lu = make_local_update(apply_fn, spec.local, features_fn)
    lu_v = jax.vmap(lu, in_axes=(None, 0, 0, 0, 0, 0, None))
    eval_fn = make_eval_fn(apply_fn)
    eval_v = jax.vmap(lambda p, cx, cy, cm: eval_fn(p, cx, cy, cm),
                      in_axes=(None, 0, 0, 0))
    need_losses = "loss_all" in fn.requires
    need_full_sel = "full_sel" in fn.requires
    need_full_all = "full_all" in fn.requires
    if need_full_all:
        # DivFL's ideal setting — the server's own grad-poll builder,
        # so the drivers can't drift apart
        grad_all_v = make_grad_all(apply_fn, spec.local)
    time_varying = scenario.time_varying
    has_entropies = fn.entropies is not None
    metrics = make_metrics(MetricsSpec(tuple(spec.telemetry)), fn=fn,
                           num_clients=cfg_n, num_select=cfg_k)
    # class count for the selection group's true-entropy ground truth
    # (host-side once; the per-seed (N,) vector is computed inside
    # run_seed from the seed's own partition, so it vmaps)
    want_true_ent = metrics.spec.enabled("selection")
    n_cls = int(jnp.max(y)) + 1 if want_true_ent else 0

    def run_seed(params0, sstate0, part: Partition, round_keys):
        idx, mask = part.idx, part.mask
        true_ent = (client_true_entropy(y[idx], mask, n_cls)
                    if want_true_ent else None)
        ex0 = init_extra(spec.local, params0) if has_extras else None
        extras0 = (jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg_n,) + l.shape), ex0)
            if ex0 else {})

        def round_step(carry, xs):
            params, extras, sstate, telc = carry
            if need_full_all:          # round_keys rows are (kr, kg)
                t, key_pair = xs
                kr, kg = key_pair[0], key_pair[1]
            else:
                t, kr = xs
            k_sel, k_loc = jax.random.split(kr)
            if time_varying:
                avail = availability_mask(scenario, cfg_n, t,
                                          jax.random.fold_in(kr, 1))
                ids, sstate = masked_select(fn, sstate, t, k_sel, avail,
                                            jax.random.fold_in(kr, 2))
            else:
                ids, sstate = fn.select(sstate, t, k_sel)
            rngs = jax.random.split(k_loc, cfg_k)
            decay = jnp.float32(spec.lr_decay) ** (t // spec.lr_decay_every)
            sel_idx = idx[ids]                              # (K, cap)
            ex_sel = (_tree_stack_gather(extras, ids) if has_extras
                      else {})
            params_before = params
            new_params, new_extras, lu_metrics = lu_v(
                params, ex_sel, x[sel_idx], y[sel_idx], mask[ids], rngs,
                decay)
            if has_extras:
                extras = _tree_stack_scatter(extras, ids, new_extras)
            bias_updates = head_bias_updates_stacked(params, new_params)
            params = aggregate_params(new_params)
            losses = full_updates = None
            if need_losses:
                losses, _ = eval_v(params, x[idx], y[idx], mask)
            if need_full_all:
                full_updates = grad_all_v(params, x[idx], y[idx], mask,
                                          jax.random.split(kg, cfg_n))
            elif need_full_sel:
                full_updates = full_sel_updates(params, new_params)
            sstate = fn.update(sstate, t, ids, Observations(
                bias_updates=bias_updates, full_updates=full_updates,
                losses=losses))
            train_loss = jnp.mean(lu_metrics["train_loss"])
            telc, tel = metrics.step(telc, TelemetryCtx(
                t=t, ids=ids, state=sstate, train_loss=train_loss,
                true_entropy=true_ent, params_before=params_before,
                params_after=params, bias_updates=bias_updates,
                lr_scale=decay))
            ents = state_entropies(fn, sstate)
            ent = (jnp.mean(ents) if has_entropies
                   else jnp.float32(0.0))
            _, acc = eval_fn(params, test["x"], test["y"], test["mask"])
            return (params, extras, sstate, telc), (
                ids, train_loss, ent, acc, tel)

        ts = jnp.arange(spec.rounds, dtype=jnp.int32)
        carry0 = (params0, extras0, sstate0, metrics.init())
        _, (ids, loss, ent, acc, tel) = jax.lax.scan(
            round_step, carry0, (ts, round_keys))
        return {"selected": ids, "train_loss": loss, "mean_entropy": ent,
                "test_acc": acc, "telemetry": tel}

    return run_seed


@dataclasses.dataclass
class PairRun:
    """Everything needed to run one (scenario, selector) cell."""
    scenario: Scenario
    selector: str
    run_seed: Any                 # single-seed pure function
    params0: Any                  # stacked over seeds
    sstate0: Any
    parts: Partition              # stacked over seeds
    round_keys: jnp.ndarray       # (S, T, ...)
    overflow_frac: float

    def vmapped(self):
        return jax.jit(jax.vmap(self.run_seed))

    def serial(self):
        return jax.jit(self.run_seed)

    def seed_slice(self, i: int):
        take = lambda a: jax.tree_util.tree_map(lambda l: l[i], a)
        return (take(self.params0), take(self.sstate0), take(self.parts),
                self.round_keys[i])


def build_pair(spec: SweepSpec, scenario_name: str,
               selector: str) -> PairRun:
    """Materialize one grid cell: shared dataset, per-seed partitions /
    params / selector states / key chains, and the seed runner."""
    scn = spec.scenario(scenario_name)
    cfg = get_config(spec.arch)
    num_classes = cfg.vocab_size
    cap = spec.capacity()
    train, test, _ = make_dataset(scn, spec.samples_train,
                                  spec.samples_test, num_classes,
                                  spec.data_seed)
    init_fn, apply_fn, features = _make_model(spec, cfg, scn.data.dim)

    need_gk = "full_all" in _probe_requires(spec, selector)
    chains = [seed_keychain(s, spec.rounds, grad_keys=need_gk)
              for s in spec.seeds]
    k_inits = jnp.stack([c[0] for c in chains])
    k_sels = jnp.stack([c[1] for c in chains])
    if need_gk:     # (S, T, 2, key) rows of (round key, grad-poll key)
        round_keys = jnp.stack(
            [jnp.stack([c[2], c[3]], axis=1) for c in chains])
    else:
        round_keys = jnp.stack([c[2] for c in chains])

    part_keys = jnp.stack([scenario_key(scn, int(s)) for s in spec.seeds])
    parts = jax.vmap(lambda key: scn.partition(
        key, train["y"], num_classes, spec.num_clients, cap))(part_keys)

    params0 = jax.vmap(init_fn)(k_inits)
    params_one = jax.tree_util.tree_map(lambda l: l[0], params0)
    fn = _make_selector_fn(spec, selector,
                           head_num_classes(params_one) or 1,
                           sum(x.size for x in
                               jax.tree_util.tree_leaves(params_one)))
    sstate0 = jax.vmap(fn.init)(k_sels)
    weights = jnp.stack([_normalized_weights(np.asarray(parts.mask[i]))
                         for i in range(len(spec.seeds))])
    sstate0 = sstate0._replace(weights=weights)

    counts = np.asarray(parts.counts, np.int64)
    kept = np.asarray(parts.mask).sum()
    overflow = float(1.0 - kept / max(1, counts.sum()))

    run_seed = make_seed_runner(spec, scn, fn, apply_fn, train["x"],
                                train["y"], test, features_fn=features)
    return PairRun(scn, selector, run_seed, params0, sstate0, parts,
                   round_keys, overflow)


def run_sweep(spec: SweepSpec, progress: bool = False) -> Dict[str, Any]:
    """The full grid, seeds vmapped.  Returns per-cell per-seed raw
    trajectories plus mean±std aggregates over seeds."""
    grid: Dict[str, Any] = {}
    for scenario_name in spec.scenarios:
        for selector in spec.selectors:
            pair = build_pair(spec, scenario_name, selector)
            with trace_span(f"sweep/{scenario_name}/{selector}"):
                out = pair.vmapped()(pair.params0, pair.sstate0,
                                     pair.parts, pair.round_keys)
                out = jax.tree_util.tree_map(np.asarray, out)
            acc, ent = out["test_acc"], out["mean_entropy"]
            cell = {
                "seeds": [int(s) for s in spec.seeds],
                "selected": out["selected"],           # (S, T, K)
                "train_loss": out["train_loss"],       # (S, T)
                "test_acc": acc,
                "mean_entropy": ent,
                "final_acc": acc[:, -1].tolist(),
                "final_acc_mean": float(acc[:, -1].mean()),
                "final_acc_std": float(acc[:, -1].std()),
                "acc_mean": acc.mean(axis=0).tolist(),
                "acc_std": acc.std(axis=0).tolist(),
                "entropy_mean": ent.mean(axis=0).tolist(),
                "entropy_std": ent.std(axis=0).tolist(),
                "train_loss_mean": out["train_loss"].mean(axis=0).tolist(),
                "overflow_frac": pair.overflow_frac,
                "telemetry": out["telemetry"],         # {field: (S, T, ...)}
            }
            grid[f"{scenario_name}/{selector}"] = cell
            if progress:
                print(f"  {scenario_name:18s} {selector:8s} "
                      f"acc={cell['final_acc_mean']:.3f}"
                      f"±{cell['final_acc_std']:.3f}", flush=True)
    return {"spec": _spec_dict(spec), "grid": grid}


def run_host_reference(spec: SweepSpec, scenario_name: str, selector: str,
                       seed: int, jit_rounds: bool = False
                       ) -> Dict[str, list]:
    """One seed through the ``FederatedServer`` on the same dataset/
    partition the sweep engine uses — the parity oracle.  Default is
    the HOST loop; ``jit_rounds=True`` drives the server's scanned
    loop instead (used to pin sweep == scanned-server exactness where
    fp tie-breaking separates both from the host loop)."""
    scn = spec.scenario(scenario_name)
    if scn.time_varying:
        raise ValueError("the server loop has no availability schedule; "
                         "host references need an always-on scenario")
    cfg = get_config(spec.arch)
    num_classes = cfg.vocab_size
    cap = spec.capacity()
    train, test, _ = make_dataset(scn, spec.samples_train,
                                  spec.samples_test, num_classes,
                                  spec.data_seed)
    part = materialize(scn, seed, train, num_classes, spec.num_clients,
                       cap)
    init_fn, apply_fn, features = _make_model(spec, cfg, scn.data.dim)
    fed_cfg = FedConfig(
        num_clients=spec.num_clients, num_select=spec.num_select,
        rounds=spec.rounds, selector=selector,
        selector_kw=spec.selector_kw, local=spec.local,
        eval_every=spec.rounds, seed=seed,
        lr_decay_every=spec.lr_decay_every, lr_decay=spec.lr_decay,
        jit_rounds=jit_rounds)
    server = FederatedServer.from_partition(
        init_fn, apply_fn, fed_cfg, train["x"], train["y"], part,
        test={k: np.asarray(v) for k, v in test.items()},
        features_fn=features)
    return server.run()


def make_async_seed_runner(spec: SweepSpec, scenario: Scenario, fn,
                           apply_fn, acfg: AsyncConfig, x: jnp.ndarray,
                           y: jnp.ndarray, test: dict, features_fn=None):
    """Async counterpart of :func:`make_seed_runner`: the whole T-tick
    buffered-async experiment for ONE seed as a pure jit/vmap-compatible
    function, built on the server's own ``make_tick_step`` body so the
    standalone :class:`~repro.fed.async_server.AsyncFederatedServer`
    and the vmapped sweep can't drift apart.  The in-flight pool and
    ring buffer are ordinary carry pytrees, so they batch over the
    vmapped seed axis like the selector cache does.

    Latency tables are host-side numpy shared across seeds (the traffic
    shape is part of the scenario, like the dataset); the partition —
    and hence which client sits behind each delay — still varies per
    seed."""
    cfg_n = spec.num_clients
    k, _, _ = acfg.sizes()
    base, jitter = delay_tables(scenario.latency, cfg_n, acfg.ticks, k)
    window = max_delay(scenario.latency, base, jitter, acfg.max_lag) + 1
    jitter_dev = jnp.asarray(np.clip(jitter, 0, window - 1), jnp.int32)
    has_extras = spec.local.algo in ("feddyn", "moon")
    lu = make_local_update(apply_fn, spec.local, features_fn)
    eval_fn = make_eval_fn(apply_fn)
    time_varying = scenario.time_varying
    has_entropies = fn.entropies is not None
    k_sel = acfg.sizes()[0]
    metrics = make_metrics(MetricsSpec(tuple(spec.telemetry)), fn=fn,
                           num_clients=cfg_n, num_select=k_sel)
    want_true_ent = metrics.spec.enabled("selection")
    n_cls = int(jnp.max(y)) + 1 if want_true_ent else 0

    def run_seed(params0, sstate0, part: Partition, round_keys):
        idx, mask = part.idx, part.mask
        get_batch = lambda ids: (x[idx[ids]], y[idx[ids]], mask[ids])
        get_all = lambda: (x[idx], y[idx], mask)
        true_ent = (client_true_entropy(y[idx], mask, n_cls)
                    if want_true_ent else None)
        select_fn = None
        if time_varying:
            def select_fn(sstate, t, kr, k_sel):
                avail = availability_mask(scenario, cfg_n, t,
                                          jax.random.fold_in(kr, 1))
                return masked_select(fn, sstate, t, k_sel, avail,
                                     jax.random.fold_in(kr, 2))
        tick_step, init_runtime = make_tick_step(
            acfg, fn, lu, eval_fn, get_batch, get_all, base, window,
            select_ids=select_fn, has_extras=has_extras,
            metrics=metrics, true_entropy=true_ent)
        pool0, buf0 = init_runtime(params0)
        ex0 = init_extra(spec.local, params0) if has_extras else None
        extras0 = (jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg_n,) + l.shape), ex0)
            if ex0 else {})
        ts = jnp.arange(acfg.ticks, dtype=jnp.int32)
        carry0 = (params0, extras0, sstate0, pool0, buf0, jnp.int32(0),
                  metrics.init())
        carry, (ids, loss, ent, fired, fill, acc_c, drop, ver, tel) = \
            jax.lax.scan(tick_step, carry0, (ts, round_keys, jitter_dev))
        params = carry[0]
        _, final_acc = eval_fn(params, test["x"], test["y"],
                               test["mask"])
        mean_ent = (jnp.mean(ent, axis=1) if has_entropies
                    else jnp.zeros_like(loss))
        return {"selected": ids, "train_loss": loss,
                "mean_entropy": mean_ent, "fired": fired,
                "buffer_fill": fill, "accepted": acc_c, "dropped": drop,
                "version": ver, "final_acc": final_acc,
                "telemetry": tel}

    return run_seed


def build_async_pair(spec: SweepSpec, scenario_name: str, selector: str,
                     capacity: int = 0, threshold: int = 0,
                     beta: float = 0.5, server_mix: float = 0.0,
                     max_lag: int = 16) -> Tuple[PairRun, AsyncConfig]:
    """Materialize one async grid cell.  Same dataset / partition /
    params / key chains as :func:`build_pair` (so identity latency with
    ``capacity = threshold = K`` is the sync cell bit-for-bit), but the
    runner drives the buffered-async tick loop and the selector gets a
    staled-id ring wide enough for one aggregation's M ids."""
    unmet = _probe_requires(spec, selector) - _ASYNC_SCANNABLE
    if unmet:
        raise ValueError(
            f"async sweep unsupported for selector {selector!r} (needs "
            f"{sorted(unmet)}; an every-tick all-clients poll has no "
            "async semantics)")
    k = spec.num_select
    m = (int(threshold) or k)
    kw = dict(spec.selector_kw or {})
    kw.setdefault("stale_slots", -(-m // k))
    spec = dataclasses.replace(spec, selector_kw=kw)
    scn = spec.scenario(scenario_name)
    acfg = AsyncConfig(
        num_clients=spec.num_clients, num_select=k, ticks=spec.rounds,
        selector=selector, selector_kw=kw, local=spec.local,
        capacity=capacity, threshold=threshold, beta=beta,
        server_mix=server_mix, latency=scn.latency, max_lag=max_lag,
        lr_decay_every=spec.lr_decay_every, lr_decay=spec.lr_decay)
    cfg = get_config(spec.arch)
    num_classes = cfg.vocab_size
    cap = spec.capacity()
    train, test, _ = make_dataset(scn, spec.samples_train,
                                  spec.samples_test, num_classes,
                                  spec.data_seed)
    init_fn, apply_fn, features = _make_model(spec, cfg, scn.data.dim)

    chains = [seed_keychain(s, spec.rounds) for s in spec.seeds]
    k_inits = jnp.stack([c[0] for c in chains])
    k_sels = jnp.stack([c[1] for c in chains])
    round_keys = jnp.stack([c[2] for c in chains])

    part_keys = jnp.stack([scenario_key(scn, int(s)) for s in spec.seeds])
    parts = jax.vmap(lambda key: scn.partition(
        key, train["y"], num_classes, spec.num_clients, cap))(part_keys)

    params0 = jax.vmap(init_fn)(k_inits)
    params_one = jax.tree_util.tree_map(lambda l: l[0], params0)
    fn = _make_selector_fn(spec, selector,
                           head_num_classes(params_one) or 1,
                           sum(x.size for x in
                               jax.tree_util.tree_leaves(params_one)))
    sstate0 = jax.vmap(fn.init)(k_sels)
    weights = jnp.stack([_normalized_weights(np.asarray(parts.mask[i]))
                         for i in range(len(spec.seeds))])
    sstate0 = sstate0._replace(weights=weights)

    counts = np.asarray(parts.counts, np.int64)
    kept = np.asarray(parts.mask).sum()
    overflow = float(1.0 - kept / max(1, counts.sum()))

    run_seed = make_async_seed_runner(spec, scn, fn, apply_fn, acfg,
                                      train["x"], train["y"], test,
                                      features_fn=features)
    return PairRun(scn, selector, run_seed, params0, sstate0, parts,
                   round_keys, overflow), acfg


def run_async_sweep(spec: SweepSpec, capacity: int = 0,
                    threshold: int = 0, beta: float = 0.5,
                    server_mix: float = 0.0, max_lag: int = 16,
                    progress: bool = False) -> Dict[str, Any]:
    """The async grid, seeds vmapped: each cell's latency model comes
    from its scenario, so a grid over the async traffic-shape family
    (``stragglers_severe``, ``diurnal_heavy_tail``, ``flash_crowd``)
    compares selectors under increasing system heterogeneity."""
    grid: Dict[str, Any] = {}
    for scenario_name in spec.scenarios:
        for selector in spec.selectors:
            pair, acfg = build_async_pair(
                spec, scenario_name, selector, capacity=capacity,
                threshold=threshold, beta=beta, server_mix=server_mix,
                max_lag=max_lag)
            out = pair.vmapped()(pair.params0, pair.sstate0, pair.parts,
                                 pair.round_keys)
            out = jax.tree_util.tree_map(np.asarray, out)
            acc = out["final_acc"]
            cell = {
                "seeds": [int(s) for s in spec.seeds],
                "selected": out["selected"],           # (S, T, K)
                "train_loss": out["train_loss"],       # (S, T)
                "train_loss_mean": out["train_loss"].mean(axis=0).tolist(),
                "mean_entropy": out["mean_entropy"],
                "final_acc": acc.tolist(),
                "final_acc_mean": float(acc.mean()),
                "final_acc_std": float(acc.std()),
                "aggregations": out["fired"].sum(axis=1).tolist(),
                "dropped_total": out["dropped"].sum(axis=1).tolist(),
                "mean_fill": out["buffer_fill"].mean(axis=1).tolist(),
                "final_version": out["version"][:, -1].tolist(),
                "overflow_frac": pair.overflow_frac,
                "telemetry": out["telemetry"],         # {field: (S, T, ...)}
            }
            grid[f"{scenario_name}/{selector}"] = cell
            if progress:
                print(f"  {scenario_name:18s} {selector:8s} "
                      f"acc={cell['final_acc_mean']:.3f}"
                      f"±{cell['final_acc_std']:.3f} "
                      f"aggs={cell['aggregations']}", flush=True)
    return {"spec": _spec_dict(spec),
            "async": {"capacity": capacity, "threshold": threshold,
                      "beta": beta, "server_mix": server_mix,
                      "max_lag": max_lag},
            "grid": grid}


def bench_sweep(spec: SweepSpec, include_host: bool = False
                ) -> Dict[str, Any]:
    """Vmapped-seeds vs python-seed-loop wall time per grid cell.

    ``serial_engine_s`` loops the jitted single-seed runner (compile
    excluded for both, so the delta is pure batching); with
    ``include_host`` the FederatedServer host loop is timed as-is —
    per-instance compiles included, because that is what the "one run
    at a time" workflow actually pays."""
    out: Dict[str, Any] = {
        "what": "vmapped multi-seed sweep vs python seed loop",
        "seeds": [int(s) for s in spec.seeds],
        "rounds": spec.rounds, "num_clients": spec.num_clients,
        "env": env_stamp(),
        "grid": {},
    }
    for scenario_name in spec.scenarios:
        for selector in spec.selectors:
            pair = build_pair(spec, scenario_name, selector)
            args = (pair.params0, pair.sstate0, pair.parts,
                    pair.round_keys)
            vrun = pair.vmapped()
            jax.block_until_ready(vrun(*args))            # compile
            t0 = time.perf_counter()
            jax.block_until_ready(vrun(*args))
            vmapped_s = time.perf_counter() - t0

            srun = pair.serial()
            jax.block_until_ready(srun(*pair.seed_slice(0)))   # compile
            t0 = time.perf_counter()
            for i in range(len(spec.seeds)):
                jax.block_until_ready(srun(*pair.seed_slice(i)))
            serial_s = time.perf_counter() - t0

            cell = {"vmapped_s": vmapped_s, "serial_engine_s": serial_s,
                    "speedup_vs_serial": serial_s / vmapped_s}
            # the server loop has no availability schedule, so the
            # host-loop baseline only exists for always-on scenarios
            if include_host and not pair.scenario.time_varying:
                t0 = time.perf_counter()
                for s in spec.seeds:
                    run_host_reference(spec, scenario_name, selector,
                                       int(s))
                cell["host_loop_s"] = time.perf_counter() - t0
                cell["speedup_vs_host"] = cell["host_loop_s"] / vmapped_s
            out["grid"][f"{scenario_name}/{selector}"] = cell
            print(f"  {scenario_name:18s} {selector:8s} "
                  f"vmapped={vmapped_s:6.2f}s  serial={serial_s:6.2f}s  "
                  f"({cell['speedup_vs_serial']:.2f}x)"
                  + (f"  host={cell['host_loop_s']:6.2f}s"
                     if "host_loop_s" in cell else ""), flush=True)
    return out


def _spec_dict(spec: SweepSpec) -> Dict[str, Any]:
    d = dataclasses.asdict(spec)
    d["scenarios"] = list(d["scenarios"])
    d["selectors"] = list(d["selectors"])
    d["seeds"] = [int(s) for s in d["seeds"]]
    d["local"] = dataclasses.asdict(spec.local)
    d["data"] = None if spec.data is None else dataclasses.asdict(spec.data)
    return d
