"""Device-resident heterogeneity scenarios + the vmapped sweep engine.

The paper's claim is that HiCS-FL adapts *across heterogeneity
profiles* (§4.1, App. A.10); this package is the machinery that makes
evaluating that claim cheap: partitions are fixed-capacity device
pytrees a ``vmap`` axis can batch, scenarios are declarative registry
entries, and a multi-seed × multi-scenario × multi-selector sweep runs
as ONE jitted-and-vmapped program per grid cell.

Quickstart (3 lines)::

    from repro.scenarios import SweepSpec, run_sweep
    res = run_sweep(SweepSpec(scenarios=("mixed_80_20", "dir_mild"),
                              selectors=("hics", "random"), seeds=(0, 1)))
    print({k: v["final_acc_mean"] for k, v in res["grid"].items()})

Scenario registry → paper map:

  =================  =====================================================
  name               instantiates
  =================  =====================================================
  iid                no-heterogeneity sanity baseline
  dir_mild           App. A.10 single-α Dirichlet, α = 0.5
  dir_severe         §4.1 setting (3): every client severely imbalanced
  mixed_80_20        §4.1 setting (1): α = {1e-3..1e-2} ∪ {0.5}
  mixed_80_20_mild   §4.1 setting (2): α = {1e-3..1e-2} ∪ {0.2}
  shards2            pathological 2-label shards (McMahan; the regime
                     Briggs et al. arXiv:2004.11791 clusters on)
  quantity_skew      |B_k| ∝ Dir(β), labels IID — beyond the paper,
                     stresses the p_k ∝ |B_k| stage-2 sampler (Eq. 10)
  flaky_severe       severe skew + 30% per-round dropout, availability
                     fed into select as a mask (Fu arXiv:2211.01549 §V)
  diurnal_mixed      setting (1) under staggered duty-cycle windows
  stragglers_severe  severe skew + a 30% straggler cohort (async server)
  diurnal_heavy_tail setting (1), diurnal windows + lognormal latency
  flash_crowd        setting (1) with periodic burst arrivals
  =================  =====================================================

Modules: ``partition_jax`` (pure-JAX key-derived partitioner),
``registry`` (Scenario specs + dataset materialization),
``availability`` (time-varying client masks + the ``masked_select``
combinator), ``sweep`` (the vmapped engine, parity oracle and bench).
"""
from repro.scenarios.availability import (availability_mask, masked_select,
                                          replace_unavailable)
from repro.scenarios.partition_jax import (Partition, pack_assignment,
                                           partition_device,
                                           partition_label_distributions)
from repro.scenarios.registry import (SCENARIOS, Scenario, get_scenario,
                                      make_dataset, materialize,
                                      scenario_key)
from repro.scenarios.sweep import (SweepSpec, bench_sweep,
                                   build_async_pair, build_pair,
                                   run_async_sweep, run_host_reference,
                                   run_sweep, seed_keychain)

__all__ = [
    "availability_mask", "masked_select", "replace_unavailable",
    "Partition", "pack_assignment", "partition_device",
    "partition_label_distributions",
    "SCENARIOS", "Scenario", "get_scenario", "make_dataset",
    "materialize", "scenario_key",
    "SweepSpec", "bench_sweep", "build_async_pair", "build_pair",
    "run_async_sweep", "run_host_reference", "run_sweep",
    "seed_keychain",
]
