"""Declarative heterogeneity scenarios + the named registry.

A :class:`Scenario` bundles everything that makes one evaluation regime
reproducible from a key: the partition scheme (kind + its knobs), the
synthetic-data spec, and a client-availability schedule.  Scenarios are
frozen dataclasses — hashable, serializable, and cheap to cross-product
with selectors and seeds in the sweep engine.

``SCENARIOS`` maps names to specs; see ``repro.scenarios.__init__`` for
the name → paper-section table.  ``materialize`` turns (scenario, seed)
into device-resident client data: the base dataset is derived from the
scenario's ``data_seed`` (shared across sweep seeds so every seed sees
the same task), while the partition is derived from the sweep seed via
``fold_in`` — the axis a multi-seed vmap batches.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticSpec, make_train_test
from repro.fed.latency import LatencySpec
from repro.scenarios.partition_jax import Partition, partition_device


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One heterogeneity regime, fully declarative."""
    name: str
    kind: str = "dirichlet"       # dirichlet|multi_alpha|shards|quantity|iid
    alphas: Tuple[float, ...] = (0.5,)
    labels_per_client: int = 2    # shards
    beta: float = 0.5             # quantity skew concentration
    availability: str = "always"  # always | dropout | blocks
    avail_p: float = 0.0          # dropout prob / blocks off-duty fraction
    avail_period: int = 4         # blocks cycle length (rounds)
    data: SyntheticSpec = dataclasses.field(default_factory=SyntheticSpec)
    #: arrival-latency model for the buffered-async server (sync
    #: drivers ignore it; identity = async degenerates to sync)
    latency: LatencySpec = dataclasses.field(default_factory=LatencySpec)
    paper: str = ""               # paper section this regime instantiates

    def partition(self, key: jax.Array, labels: jnp.ndarray,
                  num_classes: int, num_clients: int,
                  cap: int) -> Partition:
        """Key-derived device partition for this scenario (vmappable)."""
        return partition_device(
            key, labels, num_classes, num_clients, self.kind, cap,
            alphas=self.alphas, labels_per_client=self.labels_per_client,
            beta=self.beta)

    @property
    def time_varying(self) -> bool:
        return self.availability != "always"


#: §4.1's FMNIST-block concentration settings, reused across registries.
SETTING1 = (0.001, 0.002, 0.005, 0.01, 0.5)
SETTING2 = (0.001, 0.002, 0.005, 0.01, 0.2)

SCENARIOS: Dict[str, Scenario] = {s.name: s for s in (
    Scenario("iid", kind="iid",
             paper="sanity baseline (no heterogeneity)"),
    Scenario("dir_mild", kind="dirichlet", alphas=(0.5,),
             paper="App. A.10 single-α Dirichlet, α=0.5"),
    Scenario("dir_severe", kind="dirichlet", alphas=(0.001,),
             paper="§4.1 setting (3): all clients severely imbalanced"),
    Scenario("mixed_80_20", kind="multi_alpha", alphas=SETTING1,
             paper="§4.1 setting (1): 80% severe + 20% balanced"),
    Scenario("mixed_80_20_mild", kind="multi_alpha", alphas=SETTING2,
             paper="§4.1 setting (2): 80% severe + 20% mild"),
    Scenario("shards2", kind="shards", labels_per_client=2,
             paper="pathological 2-label shards (McMahan; Briggs "
                   "arXiv:2004.11791 motivates clustering on it)"),
    Scenario("quantity_skew", kind="quantity", beta=0.5,
             paper="beyond the paper: |B_k| ∝ Dir(0.5), labels IID — "
                   "stresses the p_k∝|B_k| stage-2 sampler"),
    Scenario("flaky_severe", kind="dirichlet", alphas=(0.01,),
             availability="dropout", avail_p=0.3,
             paper="beyond the paper: severe skew + 30% per-round "
                   "client dropout (Fu arXiv:2211.01549 §V)"),
    Scenario("diurnal_mixed", kind="multi_alpha", alphas=SETTING1,
             availability="blocks", avail_p=0.25, avail_period=4,
             paper="beyond the paper: setting (1) with staggered "
                   "diurnal availability windows"),
    # --- async traffic-shape family (repro.fed.async_server) ----------
    Scenario("stragglers_severe", kind="dirichlet", alphas=(0.01,),
             latency=LatencySpec(kind="stragglers", straggler_frac=0.3,
                                 straggler_delay=6),
             paper="beyond the paper: severe skew + a 30% straggler "
                   "cohort 6 ticks slow (FedBuff-style system "
                   "heterogeneity; Fu arXiv:2211.01549 §IV)"),
    Scenario("diurnal_heavy_tail", kind="multi_alpha", alphas=SETTING1,
             availability="blocks", avail_p=0.25, avail_period=4,
             latency=LatencySpec(kind="lognormal", mu=0.3, scale=0.9),
             paper="beyond the paper: setting (1), diurnal windows + "
                   "heavy-tail lognormal arrival latency"),
    Scenario("flash_crowd", kind="multi_alpha", alphas=SETTING1,
             latency=LatencySpec(kind="flash_crowd", period=6),
             paper="beyond the paper: setting (1) with periodic burst "
                   "arrivals — the ring buffer's overflow stress test"),
)}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; known: "
                       f"{sorted(SCENARIOS)}") from None


def scenario_key(scenario: Scenario, seed: int) -> jax.Array:
    """Partition PRNG key: scenario identity ⊕ sweep seed.  Stable
    across processes (crc32, not ``hash``) and independent of the model
    / selector key chains, so adding scenarios never perturbs runs."""
    base = jax.random.PRNGKey(zlib.crc32(scenario.name.encode())
                              & 0x7FFFFFFF)
    return jax.random.fold_in(base, int(seed))


def make_dataset(scenario: Scenario, samples_train: int, samples_test: int,
                 num_classes: int, data_seed: int = 0):
    """Scenario's base dataset (shared across sweep seeds): train/test
    split of the synthetic Gaussian-mixture task."""
    rng = np.random.default_rng(
        (zlib.crc32(scenario.name.encode()) ^ data_seed) & 0x7FFFFFFF)
    data_spec = dataclasses.replace(scenario.data, num_classes=num_classes)
    train, test, protos = make_train_test(rng, data_spec, samples_train,
                                          samples_test)
    as_dev = lambda d: {k: jnp.asarray(v) for k, v in d.items()}
    return as_dev(train), as_dev(test), protos


def materialize(scenario: Scenario, seed: int, train: dict,
                num_classes: int, num_clients: int, cap: int) -> Partition:
    """(scenario, seed) → device partition of the shared train set."""
    return scenario.partition(scenario_key(scenario, seed), train["y"],
                              num_classes, num_clients, cap)
