"""Pure-JAX, key-derived client partitions with a fixed-capacity layout.

The host partitioner (``repro.fed.partition``) returns ragged index
lists — fine for one experiment, fatal for a ``vmap`` axis.  Here a
partition is a :class:`Partition` pytree of fixed-shape device arrays

    idx    (N, cap) int32    row indices into the dataset
    mask   (N, cap) float32  1.0 where the row is a real sample
    counts (N,)     int32    true client sizes (before the cap clip)

so a *batch of partitions* (one per seed) is just the same pytree with
a leading seed axis, and the whole sweep engine
(:mod:`repro.scenarios.sweep`) can vmap over it.

Mechanism: every scheme is expressed as a per-sample *assignment*
vector ``assign (S,) ∈ [0, N)`` drawn with fixed-shape primitives
(Gumbel-argmax categoricals over per-class client log-proportions),
then packed into the padded layout by one stable argsort.  The
Dirichlet scheme draws per-class client proportions via
``jax.random.loggamma`` — stable down to the paper's α = 10⁻³, where
ordinary f32 gamma samples underflow to 0 — and assigns each sample
multinomially, the standard device-friendly variant of the paper's
App. A.10 largest-remainder split (identical in distribution over
proportions; counts differ by multinomial noise only, which the shared
invariant tests bound).

Samples beyond ``cap`` for an overfull client are dropped (mask 0);
``counts`` keeps the true size so callers can report the overflow.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_LOG_FLOOR = 1e-30


class Partition(NamedTuple):
    """Fixed-capacity device-resident partition (see module docstring)."""
    idx: jnp.ndarray      # (N, cap) int32
    mask: jnp.ndarray     # (N, cap) float32
    counts: jnp.ndarray   # (N,) int32


def pack_assignment(assign: jnp.ndarray, num_clients: int,
                    cap: int) -> Partition:
    """Pack a per-sample client-assignment vector into a Partition.

    One stable argsort groups samples by client; client k's rows then
    occupy a contiguous span, gathered into the (N, cap) layout with a
    clamped position index.  Padded slots point at row 0 (a valid row —
    the mask, not the value, makes them inert)."""
    s = assign.shape[0]
    order = jnp.argsort(assign)                       # stable
    counts = jnp.bincount(assign, length=num_clients)
    starts = jnp.concatenate(
        [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = starts[:, None] + jnp.arange(cap)[None, :]
    valid = jnp.arange(cap)[None, :] < jnp.minimum(counts, cap)[:, None]
    idx = jnp.where(valid, order[jnp.clip(pos, 0, s - 1)], 0)
    return Partition(idx.astype(jnp.int32), valid.astype(jnp.float32),
                     counts.astype(jnp.int32))


def _equal_split_groups(total: int, n_groups: int) -> np.ndarray:
    """group id per position, matching ``np.array_split`` sizes."""
    sizes = [len(a) for a in np.array_split(np.arange(total), n_groups)]
    return np.repeat(np.arange(n_groups), sizes)


def dirichlet_assign(key: jax.Array, labels: jnp.ndarray, num_classes: int,
                     num_clients: int, alphas: Sequence[float]
                     ) -> jnp.ndarray:
    """Multi-α Dirichlet assignment (paper App. A.10 / §4.1 settings).

    With one α this is the single-concentration scheme; with several,
    clients and data are both equal-split into ``len(alphas)`` cohorts
    and each data slice is partitioned over its client group with its
    own α — exactly the host ``multi_alpha_partition`` structure.
    """
    s = labels.shape[0]
    n_groups = len(alphas)
    k_perm, k_gamma, k_cat = jax.random.split(key, 3)
    group_of_client = jnp.asarray(_equal_split_groups(num_clients, n_groups))
    alpha_per_client = jnp.asarray(np.asarray(alphas, np.float32))[
        group_of_client]
    # per-class, per-client log Dirichlet proportions (unnormalized —
    # Gumbel-argmax is invariant to the per-class normalizer)
    logp = jax.random.loggamma(
        k_gamma, jnp.broadcast_to(alpha_per_client[None, :],
                                  (num_classes, num_clients)))
    logits = logp[labels]                                  # (S, N)
    if n_groups > 1:
        perm = jax.random.permutation(k_perm, s)
        group_pos = jnp.asarray(_equal_split_groups(s, n_groups))
        group_of_sample = jnp.zeros(s, jnp.int32).at[perm].set(
            group_pos.astype(jnp.int32))
        logits = jnp.where(group_of_client[None, :]
                           == group_of_sample[:, None], logits, -jnp.inf)
    g = jax.random.gumbel(k_cat, logits.shape, jnp.float32)
    return jnp.argmax(logits + g, axis=1).astype(jnp.int32)


def shards_assign(key: jax.Array, labels: jnp.ndarray, num_clients: int,
                  labels_per_client: int) -> jnp.ndarray:
    """Pathological label-skew: label-sorted data cut into N·L shards,
    each client dealt L shards (McMahan et al.'s FedAvg partition)."""
    s = labels.shape[0]
    num_shards = num_clients * labels_per_client
    shard_size = max(1, s // num_shards)
    order = jnp.argsort(labels)                       # stable label sort
    shard_of_pos = jnp.clip(jnp.arange(s) // shard_size, 0, num_shards - 1)
    perm = jax.random.permutation(key, num_shards)
    client_of_shard = (perm // labels_per_client).astype(jnp.int32)
    return jnp.zeros(s, jnp.int32).at[order].set(
        client_of_shard[shard_of_pos])


def quantity_assign(key: jax.Array, num_samples: int, num_clients: int,
                    beta: float) -> jnp.ndarray:
    """Quantity skew: label-agnostic sizes ∝ Dir(β) over clients."""
    k_gamma, k_cat = jax.random.split(key)
    logq = jax.random.loggamma(
        k_gamma, jnp.full((num_clients,), float(beta), jnp.float32))
    g = jax.random.gumbel(k_cat, (num_samples, num_clients), jnp.float32)
    return jnp.argmax(logq[None, :] + g, axis=1).astype(jnp.int32)


def iid_assign(key: jax.Array, num_samples: int,
               num_clients: int) -> jnp.ndarray:
    """Exactly-balanced IID deal (round-robin under a permutation)."""
    perm = jax.random.permutation(key, num_samples)
    return jnp.zeros(num_samples, jnp.int32).at[perm].set(
        (jnp.arange(num_samples) % num_clients).astype(jnp.int32))


def partition_device(key: jax.Array, labels: jnp.ndarray, num_classes: int,
                     num_clients: int, kind: str, cap: int, *,
                     alphas: Sequence[float] = (0.5,),
                     labels_per_client: int = 2,
                     beta: float = 0.5) -> Partition:
    """Key-derived partition of ``labels.shape[0]`` samples.

    ``kind`` ∈ {"dirichlet", "multi_alpha", "shards", "quantity",
    "iid"} — "dirichlet" and "multi_alpha" share one code path (the
    former is the latter with a single cohort).  Pure jax: jit- and
    vmap-compatible, so a stack of per-seed keys yields a stack of
    partitions in one call.
    """
    s = labels.shape[0]
    if kind in ("dirichlet", "multi_alpha"):
        assign = dirichlet_assign(key, labels, num_classes, num_clients,
                                  alphas)
    elif kind == "shards":
        assign = shards_assign(key, labels, num_clients, labels_per_client)
    elif kind == "quantity":
        assign = quantity_assign(key, s, num_clients, beta)
    elif kind == "iid":
        assign = iid_assign(key, s, num_clients)
    else:
        raise ValueError(f"unknown partition kind {kind!r}")
    return pack_assignment(assign, num_clients, cap)


def partition_label_distributions(part: Partition, labels: jnp.ndarray,
                                  num_classes: int) -> jnp.ndarray:
    """Per-client empirical label distribution (N, C) from the padded
    layout — the device analogue of
    ``repro.data.client_label_distributions``."""
    y = labels[part.idx]                               # (N, cap)
    onehot = jax.nn.one_hot(y, num_classes) * part.mask[..., None]
    cnt = onehot.sum(axis=1)                           # (N, C)
    tot = jnp.maximum(cnt.sum(axis=1, keepdims=True), 1.0)
    return cnt / tot
