"""Time-varying client availability, fed into selection as a mask.

Real federations never see the full client population each round —
devices sleep, roam, and churn.  A scenario's availability schedule is
a pure function ``(t, key) -> (N,) bool`` (so it vmaps over seeds and
scans over rounds), and :func:`masked_select` is the generic combinator
that applies it to ANY functional selector without touching the
selector's own code:

  1. the selector sees a state whose weights are zeroed for
     unavailable clients (stage-2 / multinomial samplers then avoid
     them on their own);
  2. any unavailable client that still slips through (e.g. HiCS-FL's
     coverage sweep, or a cluster whose members are all offline) is
     replaced by a Gumbel draw ∝ p_k from the available-and-unchosen
     pool.

If fewer than K clients are available the surplus picks are kept as-is
(the round proceeds under-provisioned rather than deadlocking) — the
registry's stock schedules keep E[#available] well above K.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.selectors.functional import FunctionalSelector, SelectorState

_LOG_FLOOR = 1e-30


def availability_mask(scenario, num_clients: int, t, key: jax.Array
                      ) -> jnp.ndarray:
    """(N,) bool availability for round ``t`` (pure; traced-``t`` safe).

    kinds: "always" — all on; "dropout" — iid Bernoulli(1 − p) per
    client per round; "blocks" — staggered duty cycles: client k is
    offline for ``round(p·period)`` rounds of every ``period``, with
    phase k mod period (a crude diurnal model).
    """
    n = num_clients
    if scenario.availability == "always":
        return jnp.ones(n, bool)
    if scenario.availability == "dropout":
        return jax.random.bernoulli(key, 1.0 - scenario.avail_p, (n,))
    if scenario.availability == "blocks":
        period = max(1, int(scenario.avail_period))
        off = int(round(scenario.avail_p * period))
        phase = (t + jnp.arange(n)) % period
        return phase >= off
    raise ValueError(f"unknown availability {scenario.availability!r}")


def replace_unavailable(key: jax.Array, ids: jnp.ndarray,
                        avail: jnp.ndarray,
                        weights: jnp.ndarray) -> jnp.ndarray:
    """Swap unavailable picks for Gumbel draws ∝ weights from the
    available-and-unchosen pool (fixed-shape, jit/vmap-compatible)."""
    k = ids.shape[0]
    n = avail.shape[0]
    chosen = jnp.zeros(n, bool).at[ids].set(True)
    ok = avail[ids]                                  # (K,) keepers
    pool = avail & ~chosen
    logw = jnp.log(jnp.clip(weights, _LOG_FLOOR, None)).astype(jnp.float32)
    g = jax.random.gumbel(key, (n,), jnp.float32)
    cand = jax.lax.top_k(jnp.where(pool, logw + g, -jnp.inf), k)[1]
    rank = jnp.clip(jnp.cumsum(~ok) - 1, 0, k - 1)   # i-th bad → rank-th
    repl = cand[rank]
    # only substitute when the candidate is genuinely from the pool
    # (top_k over all-(-inf) rows returns arbitrary indices)
    use = ~ok & pool[repl]
    return jnp.where(use, repl, ids)


def masked_select(fn: FunctionalSelector, state: SelectorState, t,
                  key: jax.Array, avail: jnp.ndarray,
                  repl_key: jax.Array):
    """Run ``fn.select`` under an availability mask (see module doc).

    Returns (ids, state) like ``fn.select``; the output state keeps the
    selector's own transitions but the ORIGINAL weights — masking is
    per-round, not persistent.  For clients the replacement step
    swapped OUT, the select-transition's seen-pool marking is reverted:
    an offline client picked by a coverage sweep never trained, so it
    must stay unseen (and its Δb row unwritten) until it is actually
    observed — ``update`` marks the clients that really participated.

    Incremental-cache safety: the distance/stats cache an incremental
    selector carries in its state is only ever written from Δb rows of
    clients that really participated (``update`` stales exactly its
    ``ids``; ``select``'s refresh is a pure function of Δb, not of the
    masked weights), so masked-out clients can never poison cached rows
    — zeroed weights steer the samplers only.  Locked down in
    tests/test_incremental_selection.py.
    """
    w0 = state.weights
    masked = state._replace(weights=jnp.where(avail, w0, 0.0))
    ids0, out = fn.select(masked, t, key)
    ids = replace_unavailable(repl_key, ids0, avail, w0)
    replaced = ids != ids0
    seen = out.seen.at[ids0].set(
        jnp.where(replaced, state.seen[ids0], out.seen[ids0]))
    return ids, out._replace(
        weights=w0, seen=seen,
        unseen_count=jnp.sum(~seen).astype(jnp.int32))
