"""Pallas TPU kernel: fused temperature-softmax entropy over class blocks.

Server-side HiCS-FL computes Ĥ(D^(k)) = H(softmax(Δb^(k)/T)) for N
clients at once: input (N, C) with C = number of classes = LLM vocab
(up to 256,206 for seamless).  At that width a naive softmax+entropy
materializes three (N, C) f32 temporaries in HBM; this kernel streams C
through VMEM in blocks with the flash-attention online-softmax trick
adapted to the entropy epilogue

    H = lnZ − S/Z,   Z = Σ e^{u−m},  S = Σ e^{u−m}(u−m),  u = v/T

carrying (m, Z, S) per row across class blocks and rescaling on each
new running max:  Z' = Z·e^{m−m'} + Z_b,  S' = (S + (m−m')Z)·e^{m−m'} + S_b.

Grid: (row blocks, class blocks); the class axis is the minor
(sequential) grid dimension, so the scratch carries state row-block by
row-block.  Block shapes are MXU/VPU aligned: rows padded to 8, classes
blocked at 512 lanes (multiple of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _entropy_kernel(x_ref, o_ref, m_ref, z_ref, s_ref, *, temperature,
                    c_total, block_c):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        z_ref[...] = jnp.zeros_like(z_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

    u = x_ref[...].astype(jnp.float32) / temperature       # (bn, bc)
    # mask the tail of the last class block
    col = ci * block_c + jax.lax.broadcasted_iota(jnp.int32, u.shape, 1)
    valid = col < c_total
    u = jnp.where(valid, u, NEG_INF)

    m_prev = m_ref[...]                                     # (bn, 1)
    m_blk = jnp.max(u, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_blk)
    alpha = jnp.exp(m_prev - m_new)                         # rescale factor
    e = jnp.where(valid, jnp.exp(u - m_new), 0.0)
    z_blk = jnp.sum(e, axis=-1, keepdims=True)
    s_blk = jnp.sum(e * jnp.where(valid, u - m_new, 0.0), axis=-1,
                    keepdims=True)
    z_prev = z_ref[...]
    s_prev = s_ref[...]
    z_new = z_prev * alpha + z_blk
    s_new = (s_prev + (m_prev - m_new) * z_prev) * alpha + s_blk
    m_ref[...] = m_new
    z_ref[...] = z_new
    s_ref[...] = s_new

    @pl.when(ci == nc - 1)
    def _epilogue():
        o_ref[...] = jnp.log(z_new) - s_new / z_new


@functools.partial(jax.jit,
                   static_argnames=("temperature", "block_n", "block_c",
                                    "interpret"))
def entropy_pallas(updates: jnp.ndarray, temperature: float,
                   block_n: int = 8, block_c: int = 512,
                   interpret: bool = True) -> jnp.ndarray:
    """(N, C) -> (N,) f32 entropies.  interpret=True on CPU (the TPU is
    the compile target; this container validates in interpret mode)."""
    n, c = updates.shape
    n_pad = -(-n // block_n) * block_n
    c_pad = -(-c // block_c) * block_c
    x = jnp.pad(updates, ((0, n_pad - n), (0, c_pad - c)))
    grid = (n_pad // block_n, c_pad // block_c)
    out = pl.pallas_call(
        functools.partial(_entropy_kernel, temperature=temperature,
                          c_total=c, block_c=block_c),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, block_c),
                               lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        scratch_shapes=[
            # (m, z, s) running stats in VMEM, one lane per row
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return out[:n, 0]
