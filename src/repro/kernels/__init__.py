"""Pallas TPU kernels for the framework's compute hot-spots:

  hetero_entropy   — fused temperature-softmax entropy over class blocks
                     (HiCS-FL server at LLM-vocab scale)
  pairwise         — Eq. 9 distance: MXU-tiled Gram + arccos/λ|ΔĤ| epilogue
  decode_attention — GQA flash-decode for the serving hot loop

Each kernel has a pure-jnp oracle in ref.py; ops.py is the dispatching
public API (TPU -> compiled Pallas, CPU -> interpret/oracle).
"""
from repro.kernels.ops import (estimate_entropies, gqa_decode_attention,
                               pairwise_distances)

__all__ = ["estimate_entropies", "gqa_decode_attention",
           "pairwise_distances"]
