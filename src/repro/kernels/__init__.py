"""Pallas TPU kernels for the framework's compute hot-spots:

  fused_stats      — single-sweep entropy + L2 norm + RMS over (N, C)
                     (the pre-Gram stage of the HiCS selection step)
  hetero_entropy   — fused temperature-softmax entropy over class blocks
                     (entropy-only API; fused_stats supersedes it on the
                     selection path)
  pairwise         — Eq. 9 distance: MXU-tiled Gram + arccos/λ|ΔĤ|
                     epilogue, plus the end-to-end fused selection step
  decode_attention — GQA flash-decode for the serving hot loop

Each kernel has a pure-jnp oracle in ref.py; ops.py is the dispatching
public API (TPU -> compiled Pallas, CPU -> interpret/oracle).

All entry points are jit/scan-compatible: ``hics_selection_step`` is
the device half of the functional selector protocol
(``repro.core.selectors.functional``) and runs *inside* the scanned
``round_step`` when ``FederatedServer`` is driven with
``jit_rounds=True`` — no host round trip between the cohort step and
the next selection.
"""
from repro.kernels.ops import (estimate_entropies, fused_row_stats,
                               gqa_decode_attention, hics_selection_step,
                               pairwise_distances)

__all__ = ["estimate_entropies", "fused_row_stats",
           "gqa_decode_attention", "hics_selection_step",
           "pairwise_distances"]
