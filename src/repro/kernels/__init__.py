"""Pallas TPU kernels for the framework's compute hot-spots:

  fused_stats      — single-sweep entropy + L2 norm + RMS over (N, C)
                     (the pre-Gram stage of the HiCS selection step)
  gram_update      — K-row incremental refresh of a cached distance
                     matrix (Alg. 1 replaces K rows per round, so the
                     strip is O(K·N·C) vs the full step's O(N²·C)),
                     with a pluggable epilogue: arccos+λ|ΔĤ| (Eq. 9,
                     HiCS), cosine (Clustered Sampling) or L2 (DivFL)
  hetero_entropy   — fused temperature-softmax entropy over class blocks
                     (entropy-only API; fused_stats supersedes it on the
                     selection path)
  pairwise         — Eq. 9 distance: MXU-tiled Gram + arccos/λ|ΔĤ|
                     epilogue, plus the end-to-end fused selection step
  decode_attention — GQA flash-decode for the serving hot loop

Each kernel has a pure-jnp oracle in ref.py; ops.py is the dispatching
public API (TPU -> compiled Pallas, CPU -> interpret/oracle).

All entry points are jit/scan-compatible: ``hics_selection_step`` is
the device half of the functional selector protocol
(``repro.core.selectors.functional``) and runs *inside* the scanned
``round_step`` when ``FederatedServer`` is driven with
``jit_rounds=True`` — no host round trip between the cohort step and
the next selection.
"""
from repro.kernels.ops import (cached_feature_step, estimate_entropies,
                               fused_row_stats, gqa_decode_attention,
                               gram_row_update, hics_selection_step,
                               hics_selection_step_cached,
                               pairwise_distances)

__all__ = ["cached_feature_step", "estimate_entropies",
           "fused_row_stats", "gqa_decode_attention", "gram_row_update",
           "hics_selection_step", "hics_selection_step_cached",
           "pairwise_distances"]
