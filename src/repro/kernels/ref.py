"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: each kernel's test sweeps shapes and
dtypes and asserts allclose against these functions, and the framework
falls back to them on CPU (``repro.kernels.ops`` dispatches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def entropy_ref(updates: jnp.ndarray, temperature: float) -> jnp.ndarray:
    """H(softmax(v / T)) row-wise.  updates: (N, C) -> (N,) float32."""
    u = updates.astype(jnp.float32) / temperature
    u = u - jnp.max(u, axis=-1, keepdims=True)
    e = jnp.exp(u)
    z = jnp.sum(e, axis=-1)
    s = jnp.sum(e * u, axis=-1)
    return jnp.log(z) - s / z


def fused_stats_ref(updates: jnp.ndarray, temperature: float,
                    row_scale: jnp.ndarray | None = None):
    """Oracle for the fused stats kernel: one logical pass over (N, C).

    Returns (entropy, l2_norm, rms), each (N,) float32.  ``row_scale``
    (N,) optionally multiplies each row before the tempered softmax
    (norm/RMS are always of the raw rows) — the hook the normalized
    estimator path uses with scale = 1/RMS.
    """
    x = updates.astype(jnp.float32)
    scaled = x if row_scale is None else x * row_scale.astype(
        jnp.float32)[:, None]
    ent = entropy_ref(scaled, temperature)
    sumsq = jnp.sum(jnp.square(x), axis=-1)
    norm = jnp.sqrt(sumsq)
    rms = jnp.sqrt(sumsq / x.shape[-1])
    return ent, norm, rms


def selection_step_ref(updates: jnp.ndarray, temperature: float,
                       lam: float, normalize: bool = False):
    """Oracle for the fused HiCS selection step: (N, C) -> (Ĥ, Eq. 9 D).

    ``normalize=True`` RMS-normalizes each row before the tempered
    softmax (the magnitude-invariant estimator of
    ``core.hetero.estimate_entropy``); the angular term is unaffected
    because cosine similarity is per-row scale invariant.
    """
    x = updates.astype(jnp.float32)
    if normalize:
        rms = jnp.sqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True))
        h = entropy_ref(x / jnp.clip(rms, 1e-12, None), temperature)
    else:
        h = entropy_ref(x, temperature)
    return h, pairwise_distance_ref(x, h, lam)


def cached_selection_step_ref(updates: jnp.ndarray, dist: jnp.ndarray,
                              stats: jnp.ndarray, ids: jnp.ndarray,
                              temperature: float, lam: float,
                              normalize: bool = False,
                              eps: float = 1e-8):
    """Oracle for the INCREMENTAL HiCS selection step (Alg. 1 caching).

    Alg. 1 replaces only the K participants' Δb rows per round, so the
    other N−K rows of the Eq. 9 distance matrix are reusable.  Given the
    cached ``dist`` (N, N) and per-row ``stats`` (N, 2) = [L2 norm, Ĥ]
    from the previous round, this refreshes ONLY the rows/cols of
    ``ids`` — O(K·N·C) instead of the full step's O(N²·C) — and returns
    ``(Ĥ (N,), dist (N, N), stats (N, 2))`` with the refreshed cache.

    Row-for-row this reproduces :func:`selection_step_ref` exactly: the
    per-row entropy/norm reductions and the unit-row dot products are
    the same expressions evaluated over the gathered rows, so as long as
    every row of ``dist``/``stats`` has been refreshed since its Δb row
    last changed, the cache equals the from-scratch matrix (bit-for-bit
    at head widths where XLA's reduction tiling is row-independent; to
    f32 tolerance otherwise).  Duplicate ids are harmless (the gathered
    rows are identical) and ``ids`` of length 0 returns the cache as-is.
    """
    x = updates.astype(jnp.float32)
    n = x.shape[0]
    if ids.shape[0] == 0:
        return stats[:, 1], dist, stats
    rows = x[ids]                                         # (K, C)
    if normalize:
        rms = jnp.sqrt(jnp.mean(jnp.square(rows), axis=-1, keepdims=True))
        h_rows = entropy_ref(rows / jnp.clip(rms, 1e-12, None),
                             temperature)
    else:
        h_rows = entropy_ref(rows, temperature)
    n_rows = jnp.linalg.norm(rows, axis=-1)
    stats = stats.at[ids].set(jnp.stack([n_rows, h_rows], axis=-1))
    strip = distance_strip_ref(x, stats, ids, lam, eps=eps)
    # re-symmetrize: the row write and its transpose carry equal values
    # (dot(a, b) == dot(b, a)), so the cache stays exactly symmetric
    dist = dist.at[ids].set(strip)
    dist = dist.at[:, ids].set(strip.T)
    return stats[:, 1], dist, stats


def distance_strip_ref(updates: jnp.ndarray, stats: jnp.ndarray,
                       ids: jnp.ndarray, lam: float,
                       eps: float = 1e-8,
                       epilogue: str = "arccos") -> jnp.ndarray:
    """(N, C), (N, 2) current [norm, Ĥ] stats, (K,) ids -> (K, N)
    distance strip — the lax oracle for the ``gram_row_update`` kernel.

    ``epilogue`` picks the distance the K×N Gram product feeds:

      arccos — Eq. 9: arccos cosine + λ|ΔĤ| (stats = [norm, Ĥ]) — HiCS
      cosine — angular distance alone (stats[:, 1] ignored) — CS [11]
      l2     — Euclidean √(|a|² + |b|² − 2⟨a, b⟩) from the cached
               norms (stats[:, 1] ignored) — DivFL [2]

    Unit rows for the cosine family are built exactly as
    :func:`pairwise_distance_ref` builds them, with the cached norms
    standing in for the full norm sweep.  The true diagonal is zeroed
    for every epilogue.
    """
    x = updates.astype(jnp.float32)
    if epilogue == "l2":
        nr = stats[ids, 0]
        nc = stats[:, 0]
        dot = x[ids] @ x.T
        d = jnp.sqrt(jnp.clip(
            nr[:, None] ** 2 + nc[None, :] ** 2 - 2.0 * dot, 0.0, None))
    elif epilogue in ("arccos", "cosine"):
        unit = x / jnp.clip(stats[:, 0:1], eps, None)
        cos = jnp.clip(unit[ids] @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
        d = jnp.arccos(cos)
    else:
        raise ValueError(f"unknown epilogue {epilogue!r}; expected "
                         "'arccos', 'cosine' or 'l2'")
    d = jnp.where(ids[:, None] == jnp.arange(x.shape[0])[None, :],
                  0.0, d)
    if epilogue == "arccos":
        h_all = stats[:, 1]
        d = d + lam * jnp.abs(stats[ids, 1][:, None] - h_all[None, :])
    return d


def cached_feature_step_ref(feats: jnp.ndarray, dist: jnp.ndarray,
                            stats: jnp.ndarray, ids: jnp.ndarray,
                            metric: str = "cosine",
                            eps: float = 1e-8):
    """Oracle for the INCREMENTAL full-update distance step (CS/DivFL).

    The full-update baselines build an (N, N) similarity matrix from
    flattened-update features each round, but only the rows whose
    features changed since the last refresh need recomputing — the same
    K-row caching Alg. 1 gave HiCS, with the Eq. 9 epilogue swapped for
    the selector's own metric (``cosine`` for Clustered Sampling,
    ``l2`` for DivFL).  Given the cached ``dist`` (N, N) and per-row
    ``stats`` (N, 2) = [L2 norm, 0] this refreshes ONLY the rows/cols
    of ``ids`` — O(K·N·F) instead of O(N²·F) — and returns
    ``(dist, stats)``.  Duplicate ids are harmless; K = 0 returns the
    cache unchanged.  stats[:, 1] is carried (zero) purely so the cache
    pytree matches the HiCS layout and one state field serves all
    cached selectors.
    """
    x = feats.astype(jnp.float32)
    if ids.shape[0] == 0:
        return dist, stats
    rows = x[ids]                                         # (K, F)
    n_rows = jnp.linalg.norm(rows, axis=-1)
    stats = stats.at[ids].set(
        jnp.stack([n_rows, jnp.zeros_like(n_rows)], axis=-1))
    strip = distance_strip_ref(x, stats, ids, 0.0, eps=eps,
                               epilogue=metric)
    return _scatter_strip_symmetric(dist, strip, ids), stats


def _scatter_strip_symmetric(dist: jnp.ndarray, strip: jnp.ndarray,
                             ids: jnp.ndarray) -> jnp.ndarray:
    """Write a (K, N) strip into rows AND columns ``ids`` of ``dist``,
    keeping the result exactly symmetric.  Off-block entries get the
    strip value and its exact transpose; the K×K block is averaged with
    its transpose first because XLA's fused L2 epilogue is free to
    evaluate (u, v) and (v, u) with different instruction schedules —
    1-ulp asymmetries that an ``exactly symmetric`` invariant (the
    ``precomputed=True`` clustering fast path) cannot tolerate.
    Duplicate ids are safe: their strip rows are identical, so every
    candidate value of a contested scatter slot is equal."""
    kk = strip[:, ids]                                    # (K, K)
    dist = dist.at[ids].set(strip)
    dist = dist.at[:, ids].set(strip.T)
    return dist.at[ids[:, None], ids[None, :]].set(0.5 * (kk + kk.T))


def pairwise_distance_ref(updates: jnp.ndarray, entropies: jnp.ndarray,
                          lam: float, eps: float = 1e-8) -> jnp.ndarray:
    """Eq. 9 distance matrix.  updates (N, C), entropies (N,) -> (N, N)."""
    x = updates.astype(jnp.float32)
    norms = jnp.linalg.norm(x, axis=-1, keepdims=True)
    unit = x / jnp.clip(norms, eps, None)
    cos = jnp.clip(unit @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
    ang = jnp.arccos(cos) * (1.0 - jnp.eye(x.shape[0]))
    h = entropies.astype(jnp.float32)
    return ang + lam * jnp.abs(h[:, None] - h[None, :])


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         length: jnp.ndarray | int,
                         scale: float | None = None) -> jnp.ndarray:
    """GQA one-token decode attention.

    q: (B, H, dh); k/v: (B, S, KV, dh); length: valid cache length
    (positions >= length are masked).  H must be a multiple of KV.
    Returns (B, H, dh) float32.
    """
    B, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = (dh ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, KV, g, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bngd,bsnd->bngs", qf, kf) * scale
    pos = jnp.arange(S)
    mask = pos[None, :] < jnp.asarray(length).reshape(-1, 1)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngs,bsnd->bngd", p, vf)
    return out.reshape(B, H, dh)
