"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: each kernel's test sweeps shapes and
dtypes and asserts allclose against these functions, and the framework
falls back to them on CPU (``repro.kernels.ops`` dispatches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def entropy_ref(updates: jnp.ndarray, temperature: float) -> jnp.ndarray:
    """H(softmax(v / T)) row-wise.  updates: (N, C) -> (N,) float32."""
    u = updates.astype(jnp.float32) / temperature
    u = u - jnp.max(u, axis=-1, keepdims=True)
    e = jnp.exp(u)
    z = jnp.sum(e, axis=-1)
    s = jnp.sum(e * u, axis=-1)
    return jnp.log(z) - s / z


def pairwise_distance_ref(updates: jnp.ndarray, entropies: jnp.ndarray,
                          lam: float, eps: float = 1e-8) -> jnp.ndarray:
    """Eq. 9 distance matrix.  updates (N, C), entropies (N,) -> (N, N)."""
    x = updates.astype(jnp.float32)
    norms = jnp.linalg.norm(x, axis=-1, keepdims=True)
    unit = x / jnp.clip(norms, eps, None)
    cos = jnp.clip(unit @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
    ang = jnp.arccos(cos) * (1.0 - jnp.eye(x.shape[0]))
    h = entropies.astype(jnp.float32)
    return ang + lam * jnp.abs(h[:, None] - h[None, :])


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         length: jnp.ndarray | int,
                         scale: float | None = None) -> jnp.ndarray:
    """GQA one-token decode attention.

    q: (B, H, dh); k/v: (B, S, KV, dh); length: valid cache length
    (positions >= length are masked).  H must be a multiple of KV.
    Returns (B, H, dh) float32.
    """
    B, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = (dh ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, KV, g, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bngd,bsnd->bngs", qf, kf) * scale
    pos = jnp.arange(S)
    mask = pos[None, :] < jnp.asarray(length).reshape(-1, 1)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngs,bsnd->bngd", p, vf)
    return out.reshape(B, H, dh)
