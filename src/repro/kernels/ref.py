"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: each kernel's test sweeps shapes and
dtypes and asserts allclose against these functions, and the framework
falls back to them on CPU (``repro.kernels.ops`` dispatches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def entropy_ref(updates: jnp.ndarray, temperature: float) -> jnp.ndarray:
    """H(softmax(v / T)) row-wise.  updates: (N, C) -> (N,) float32."""
    u = updates.astype(jnp.float32) / temperature
    u = u - jnp.max(u, axis=-1, keepdims=True)
    e = jnp.exp(u)
    z = jnp.sum(e, axis=-1)
    s = jnp.sum(e * u, axis=-1)
    return jnp.log(z) - s / z


def fused_stats_ref(updates: jnp.ndarray, temperature: float,
                    row_scale: jnp.ndarray | None = None):
    """Oracle for the fused stats kernel: one logical pass over (N, C).

    Returns (entropy, l2_norm, rms), each (N,) float32.  ``row_scale``
    (N,) optionally multiplies each row before the tempered softmax
    (norm/RMS are always of the raw rows) — the hook the normalized
    estimator path uses with scale = 1/RMS.
    """
    x = updates.astype(jnp.float32)
    scaled = x if row_scale is None else x * row_scale.astype(
        jnp.float32)[:, None]
    ent = entropy_ref(scaled, temperature)
    sumsq = jnp.sum(jnp.square(x), axis=-1)
    norm = jnp.sqrt(sumsq)
    rms = jnp.sqrt(sumsq / x.shape[-1])
    return ent, norm, rms


def selection_step_ref(updates: jnp.ndarray, temperature: float,
                       lam: float, normalize: bool = False):
    """Oracle for the fused HiCS selection step: (N, C) -> (Ĥ, Eq. 9 D).

    ``normalize=True`` RMS-normalizes each row before the tempered
    softmax (the magnitude-invariant estimator of
    ``core.hetero.estimate_entropy``); the angular term is unaffected
    because cosine similarity is per-row scale invariant.
    """
    x = updates.astype(jnp.float32)
    if normalize:
        rms = jnp.sqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True))
        h = entropy_ref(x / jnp.clip(rms, 1e-12, None), temperature)
    else:
        h = entropy_ref(x, temperature)
    return h, pairwise_distance_ref(x, h, lam)


def cached_selection_step_ref(updates: jnp.ndarray, dist: jnp.ndarray,
                              stats: jnp.ndarray, ids: jnp.ndarray,
                              temperature: float, lam: float,
                              normalize: bool = False,
                              eps: float = 1e-8):
    """Oracle for the INCREMENTAL HiCS selection step (Alg. 1 caching).

    Alg. 1 replaces only the K participants' Δb rows per round, so the
    other N−K rows of the Eq. 9 distance matrix are reusable.  Given the
    cached ``dist`` (N, N) and per-row ``stats`` (N, 2) = [L2 norm, Ĥ]
    from the previous round, this refreshes ONLY the rows/cols of
    ``ids`` — O(K·N·C) instead of the full step's O(N²·C) — and returns
    ``(Ĥ (N,), dist (N, N), stats (N, 2))`` with the refreshed cache.

    Row-for-row this reproduces :func:`selection_step_ref` exactly: the
    per-row entropy/norm reductions and the unit-row dot products are
    the same expressions evaluated over the gathered rows, so as long as
    every row of ``dist``/``stats`` has been refreshed since its Δb row
    last changed, the cache equals the from-scratch matrix (bit-for-bit
    at head widths where XLA's reduction tiling is row-independent; to
    f32 tolerance otherwise).  Duplicate ids are harmless (the gathered
    rows are identical) and ``ids`` of length 0 returns the cache as-is.
    """
    x = updates.astype(jnp.float32)
    n = x.shape[0]
    if ids.shape[0] == 0:
        return stats[:, 1], dist, stats
    rows = x[ids]                                         # (K, C)
    if normalize:
        rms = jnp.sqrt(jnp.mean(jnp.square(rows), axis=-1, keepdims=True))
        h_rows = entropy_ref(rows / jnp.clip(rms, 1e-12, None),
                             temperature)
    else:
        h_rows = entropy_ref(rows, temperature)
    n_rows = jnp.linalg.norm(rows, axis=-1)
    stats = stats.at[ids].set(jnp.stack([n_rows, h_rows], axis=-1))
    strip = distance_strip_ref(x, stats, ids, lam, eps=eps)
    # re-symmetrize: the row write and its transpose carry equal values
    # (dot(a, b) == dot(b, a)), so the cache stays exactly symmetric
    dist = dist.at[ids].set(strip)
    dist = dist.at[:, ids].set(strip.T)
    return stats[:, 1], dist, stats


def distance_strip_ref(updates: jnp.ndarray, stats: jnp.ndarray,
                       ids: jnp.ndarray, lam: float,
                       eps: float = 1e-8) -> jnp.ndarray:
    """(N, C), (N, 2) current [norm, Ĥ] stats, (K,) ids -> (K, N) Eq. 9
    distance strip — the lax oracle for the ``gram_row_update`` kernel.
    Unit rows are built exactly as :func:`pairwise_distance_ref` builds
    them, with the cached norms standing in for the full norm sweep."""
    x = updates.astype(jnp.float32)
    unit = x / jnp.clip(stats[:, 0:1], eps, None)
    cos = jnp.clip(unit[ids] @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
    ang = jnp.arccos(cos)
    ang = jnp.where(ids[:, None] == jnp.arange(x.shape[0])[None, :],
                    0.0, ang)
    h_all = stats[:, 1]
    return ang + lam * jnp.abs(stats[ids, 1][:, None] - h_all[None, :])


def pairwise_distance_ref(updates: jnp.ndarray, entropies: jnp.ndarray,
                          lam: float, eps: float = 1e-8) -> jnp.ndarray:
    """Eq. 9 distance matrix.  updates (N, C), entropies (N,) -> (N, N)."""
    x = updates.astype(jnp.float32)
    norms = jnp.linalg.norm(x, axis=-1, keepdims=True)
    unit = x / jnp.clip(norms, eps, None)
    cos = jnp.clip(unit @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
    ang = jnp.arccos(cos) * (1.0 - jnp.eye(x.shape[0]))
    h = entropies.astype(jnp.float32)
    return ang + lam * jnp.abs(h[:, None] - h[None, :])


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         length: jnp.ndarray | int,
                         scale: float | None = None) -> jnp.ndarray:
    """GQA one-token decode attention.

    q: (B, H, dh); k/v: (B, S, KV, dh); length: valid cache length
    (positions >= length are masked).  H must be a multiple of KV.
    Returns (B, H, dh) float32.
    """
    B, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = (dh ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, KV, g, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bngd,bsnd->bngs", qf, kf) * scale
    pos = jnp.arange(S)
    mask = pos[None, :] < jnp.asarray(length).reshape(-1, 1)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngs,bsnd->bngd", p, vf)
    return out.reshape(B, H, dh)
