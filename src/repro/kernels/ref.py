"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contract: each kernel's test sweeps shapes and
dtypes and asserts allclose against these functions, and the framework
falls back to them on CPU (``repro.kernels.ops`` dispatches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def entropy_ref(updates: jnp.ndarray, temperature: float) -> jnp.ndarray:
    """H(softmax(v / T)) row-wise.  updates: (N, C) -> (N,) float32."""
    u = updates.astype(jnp.float32) / temperature
    u = u - jnp.max(u, axis=-1, keepdims=True)
    e = jnp.exp(u)
    z = jnp.sum(e, axis=-1)
    s = jnp.sum(e * u, axis=-1)
    return jnp.log(z) - s / z


def fused_stats_ref(updates: jnp.ndarray, temperature: float,
                    row_scale: jnp.ndarray | None = None):
    """Oracle for the fused stats kernel: one logical pass over (N, C).

    Returns (entropy, l2_norm, rms), each (N,) float32.  ``row_scale``
    (N,) optionally multiplies each row before the tempered softmax
    (norm/RMS are always of the raw rows) — the hook the normalized
    estimator path uses with scale = 1/RMS.
    """
    x = updates.astype(jnp.float32)
    scaled = x if row_scale is None else x * row_scale.astype(
        jnp.float32)[:, None]
    ent = entropy_ref(scaled, temperature)
    sumsq = jnp.sum(jnp.square(x), axis=-1)
    norm = jnp.sqrt(sumsq)
    rms = jnp.sqrt(sumsq / x.shape[-1])
    return ent, norm, rms


def selection_step_ref(updates: jnp.ndarray, temperature: float,
                       lam: float, normalize: bool = False):
    """Oracle for the fused HiCS selection step: (N, C) -> (Ĥ, Eq. 9 D).

    ``normalize=True`` RMS-normalizes each row before the tempered
    softmax (the magnitude-invariant estimator of
    ``core.hetero.estimate_entropy``); the angular term is unaffected
    because cosine similarity is per-row scale invariant.
    """
    x = updates.astype(jnp.float32)
    if normalize:
        rms = jnp.sqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True))
        h = entropy_ref(x / jnp.clip(rms, 1e-12, None), temperature)
    else:
        h = entropy_ref(x, temperature)
    return h, pairwise_distance_ref(x, h, lam)


def pairwise_distance_ref(updates: jnp.ndarray, entropies: jnp.ndarray,
                          lam: float, eps: float = 1e-8) -> jnp.ndarray:
    """Eq. 9 distance matrix.  updates (N, C), entropies (N,) -> (N, N)."""
    x = updates.astype(jnp.float32)
    norms = jnp.linalg.norm(x, axis=-1, keepdims=True)
    unit = x / jnp.clip(norms, eps, None)
    cos = jnp.clip(unit @ unit.T, -1.0 + 1e-7, 1.0 - 1e-7)
    ang = jnp.arccos(cos) * (1.0 - jnp.eye(x.shape[0]))
    h = entropies.astype(jnp.float32)
    return ang + lam * jnp.abs(h[:, None] - h[None, :])


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         length: jnp.ndarray | int,
                         scale: float | None = None) -> jnp.ndarray:
    """GQA one-token decode attention.

    q: (B, H, dh); k/v: (B, S, KV, dh); length: valid cache length
    (positions >= length are masked).  H must be a multiple of KV.
    Returns (B, H, dh) float32.
    """
    B, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = (dh ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, KV, g, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bngd,bsnd->bngs", qf, kf) * scale
    pos = jnp.arange(S)
    mask = pos[None, :] < jnp.asarray(length).reshape(-1, 1)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngs,bsnd->bngd", p, vf)
    return out.reshape(B, H, dh)
