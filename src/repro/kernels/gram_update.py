"""Pallas TPU kernel: K-row incremental update of a cached distance.

HiCS-FL's Algorithm 1 replaces only the K participating clients' Δb
rows each round, so N−K rows of the Gram/arccos distance matrix carry
over round-to-round.  This module is the device half of that caching
scheme: instead of the full (N, N) Gram product — O(N²·C) HBM traffic
and MXU work per round — it recomputes just the K×N strip

    D[u, j] = arccos( <Δb_u, Δb_j> / (|Δb_u||Δb_j|) ) + λ |Ĥ_u − Ĥ_j|

for the refreshed rows u ∈ ids, O(K·N·C), and scatters it back into
the cached matrix (rows AND columns — dot products are symmetric, so
the scatter keeps the cache exactly symmetric).

The Gram product is metric-agnostic, so the Eq. 9 arccos+λ|ΔĤ| tail is
one of three pluggable EPILOGUES applied on the last C block: "arccos"
(HiCS), "cosine" (Clustered Sampling's angular distance over full
updates) and "l2" (DivFL's Euclidean distance, rebuilt from the cached
norms via |a−b|² = |a|² + |b|² − 2⟨a, b⟩).  That one switch lets the
full-update baselines ride the SAME cached K-row path HiCS uses —
``cached_feature_step_pallas`` below — which is what puts DivFL/CS on
the scanned round loop at O(K·N·F) per round.

The strip kernel reuses the Gram tiling of ``kernels/pairwise``: (BK,
BC) × (BN, BC) partial products accumulated in a VMEM f32 scratch over
the sequential C axis, with the normalize→clip→arccos→+λ|ΔĤ| epilogue
applied on the last C block so the strip is written to HBM exactly
once.  ``gram_in_bf16`` casts both Gram operands to bf16 (f32
accumulation stays) for 2× operand bandwidth, exactly like the full
kernel.  The true diagonal is zeroed via the refreshed rows' GLOBAL
indices, which ride along as a (K, 1) int32 operand.

``cached_selection_step_pallas`` is the end-to-end incremental
selection step: gather the K rows, one fused-stats sweep over (K, C)
(entropy + L2 norm, plus the RMS-normalized second sweep when
``normalize=True``), the strip kernel, and the row/col scatter — all
inside one jit.  Grid: (K tiles, N tiles, C blocks); C minor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref
from repro.kernels.fused_stats import _fused_stats_padded
from repro.kernels.pairwise import _gram_blocks


#: strip-kernel epilogues: how the K×N Gram product becomes a distance.
#: "arccos" is Eq. 9 (HiCS); "cosine" is the angular distance alone
#: (Clustered Sampling); "l2" is Euclidean distance from the cached
#: norms (DivFL).  Static per trace — each picks a different tail of
#: VPU arithmetic on the final C block.
EPILOGUES = ("arccos", "cosine", "l2")


def _gram_row_kernel(rows_ref, x_ref, stats_r_ref, stats_c_ref, ids_ref,
                     o_ref, acc_ref, *, lam, eps, block_n, epilogue):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)
    j = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = rows_ref[...].astype(jnp.float32)     # (BK, BC) refreshed rows
    b = x_ref[...].astype(jnp.float32)        # (BN, BC) all-clients tile
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _epilogue():
        # stats lanes: [:, 0] = L2 norm, [:, 1] = entropy
        nr = stats_r_ref[..., 0:1].astype(jnp.float32)    # (BK, 1)
        ncol = stats_c_ref[..., 0:1].astype(jnp.float32)  # (BN, 1)
        if epilogue == "l2":
            # √(|a|² + |b|² − 2⟨a, b⟩) from the cached norms; the clip
            # absorbs the fp cancellation of near-identical rows
            d = jnp.sqrt(jnp.clip(
                nr * nr + (ncol * ncol).T - 2.0 * acc_ref[...], 0.0,
                None))
        else:                                 # cosine family
            denom = jnp.maximum(nr, eps) * jnp.maximum(ncol, eps).T
            cos = acc_ref[...] / denom
            cos = jnp.clip(cos, -1.0 + 1e-7, 1.0 - 1e-7)
            d = jnp.arccos(cos)
        # zero the TRUE diagonal: the strip row's global client index
        # (ids operand) against the tile's global column range
        row_id = ids_ref[..., 0:1]                        # (BK, 1) int32
        col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, d.shape,
                                                     1)
        d = jnp.where(row_id == col, 0.0, d)
        if epilogue == "arccos":
            hr = stats_r_ref[..., 1:2].astype(jnp.float32)    # (BK, 1)
            hc = stats_c_ref[..., 1:2].astype(jnp.float32)    # (BN, 1)
            d = d + lam * jnp.abs(hr - hc.T)
        o_ref[...] = d


def _gram_rows_padded(rows: jnp.ndarray, x: jnp.ndarray,
                      stats_rows: jnp.ndarray, stats_all: jnp.ndarray,
                      row_ids: jnp.ndarray, lam: float, eps: float,
                      bk: int, bn: int, block_c: int,
                      interpret: bool,
                      epilogue: str = "arccos") -> jnp.ndarray:
    """Strip kernel on already padded buffers.

    rows (k_pad, c_pad), x (n_pad, c_pad), stats (k_pad, 2)/(n_pad, 2)
    with nonzero norms on padded entries, row_ids (k_pad, 1) int32 with
    -1 on padded entries (never matches a live column).
    """
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}; expected one "
                         f"of {EPILOGUES}")
    k_pad, c_pad = rows.shape
    n_pad = x.shape[0]
    grid = (k_pad // bk, n_pad // bn, c_pad // block_c)
    return pl.pallas_call(
        functools.partial(_gram_row_kernel, lam=lam, eps=eps,
                          block_n=bn, epilogue=epilogue),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, block_c), lambda i, j, k: (i, k)),  # rows
            pl.BlockSpec((bn, block_c), lambda i, j, k: (j, k)),  # cols
            pl.BlockSpec((bk, 2), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j, k: (j, 0)),
            pl.BlockSpec((bk, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k_pad, n_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(rows, x, stats_rows, stats_all, row_ids)


_BK = 8   # strip row-tile: K is small (a cohort), one VPU sublane tile


def _strip_operands(x_pad: jnp.ndarray, stats: jnp.ndarray,
                    ids: jnp.ndarray, n: int, gram_in_bf16: bool):
    """Padded/aligned operands for the strip kernel, shared by both
    entry points so their invariants cannot drift: padded stats lanes
    carry norm 1 (never divide by eps²), padded row ids carry -1
    (never matches a live column), and the bf16 cast happens AFTER any
    f32 consumer of the buffers.  Returns (rows, x, stats_rows,
    stats_all, row_ids, k_pad)."""
    n_pad = x_pad.shape[0]
    k = ids.shape[0]
    k_pad = max(_BK, -(-k // _BK) * _BK)
    rows = jnp.pad(x_pad[ids], ((0, k_pad - k), (0, 0)))
    live = jnp.arange(n_pad) < n
    stats_all = jnp.stack(
        [jnp.where(live, jnp.pad(stats[:, 0], (0, n_pad - n)), 1.0),
         jnp.pad(stats[:, 1], (0, n_pad - n))], axis=-1)
    stats_rows = jnp.pad(stats[ids], ((0, k_pad - k), (0, 0)),
                         constant_values=1.0)
    row_ids = jnp.pad(ids.astype(jnp.int32), (0, k_pad - k),
                      constant_values=-1)[:, None]
    if gram_in_bf16:
        x_pad = x_pad.astype(jnp.bfloat16)
        rows = rows.astype(jnp.bfloat16)
    return rows, x_pad, stats_rows, stats_all, row_ids, k_pad


@functools.partial(jax.jit,
                   static_argnames=("lam", "block_n", "block_c",
                                    "gram_in_bf16", "interpret",
                                    "epilogue"))
def gram_row_update_pallas(updates: jnp.ndarray, stats: jnp.ndarray,
                           ids: jnp.ndarray, lam: float = 10.0,
                           block_n: int = 128, block_c: int = 512,
                           gram_in_bf16: bool = False,
                           interpret: bool = True,
                           epilogue: str = "arccos") -> jnp.ndarray:
    """(N, C), (N, 2) stats, (K,) ids -> (K, N) distance strip.

    ``epilogue`` picks the distance (see :data:`EPILOGUES`): "arccos"
    is the Eq. 9 strip, "cosine"/"l2" serve the full-update baselines.
    ``stats`` must already hold the CURRENT [norm, Ĥ] of every row
    (including the refreshed ones); this is just the tiled strip
    product + epilogue.  ``cached_selection_step_pallas`` wraps it with
    the stats refresh and the cache scatter.
    """
    n, c = updates.shape
    k = ids.shape[0]
    bn, n_pad, c_pad = _gram_blocks(n, c, block_n, block_c)
    x = jnp.pad(updates.astype(jnp.float32), ((0, n_pad - n),
                                              (0, c_pad - c)))
    rows, x, stats_rows, stats_all, row_ids, _ = _strip_operands(
        x, stats, ids, n, gram_in_bf16)
    strip = _gram_rows_padded(rows, x, stats_rows, stats_all, row_ids,
                              lam, 1e-8, _BK, bn, block_c, interpret,
                              epilogue=epilogue)
    return strip[:k, :n]


@functools.partial(jax.jit,
                   static_argnames=("temperature", "lam", "normalize",
                                    "block_n", "block_c", "gram_in_bf16",
                                    "interpret"))
def cached_selection_step_pallas(updates: jnp.ndarray, dist: jnp.ndarray,
                                 stats: jnp.ndarray, ids: jnp.ndarray,
                                 temperature: float, lam: float = 10.0,
                                 normalize: bool = False,
                                 block_n: int = 128, block_c: int = 512,
                                 gram_in_bf16: bool = False,
                                 interpret: bool = True):
    """Incremental HiCS selection step, kernel path.

    (N, C) Δb + cached (dist (N, N), stats (N, 2)) + (K,) refreshed ids
    -> (Ĥ (N,), dist, stats) with rows/cols of ``ids`` recomputed and
    re-symmetrized — O(K·N·C) instead of O(N²·C).  Same epilogue
    arithmetic as ``hics_selection_step_pallas`` (dot-then-divide
    cosine, f32 accumulation), so cached and from-scratch kernels agree
    row-for-row.  K = 0 returns the cache unchanged.
    """
    n, c = updates.shape
    k = ids.shape[0]
    if k == 0:
        return stats[:, 1], dist, stats
    bn, n_pad, c_pad = _gram_blocks(n, c, block_n, block_c)
    k_pad = max(_BK, -(-k // _BK) * _BK)
    x = jnp.pad(updates.astype(jnp.float32), ((0, n_pad - n),
                                              (0, c_pad - c)))
    rows_f32 = jnp.pad(x[ids], ((0, k_pad - k), (0, 0)))  # (k_pad, c_pad)
    inv_t = jnp.full((k_pad, 1), 1.0 / temperature, jnp.float32)
    ent_r, norm_r, rms_r = _fused_stats_padded(rows_f32, inv_t, c, 8,
                                               block_c, interpret)
    if normalize:
        scale = 1.0 / (jnp.clip(rms_r, 1e-12, None)[:, None]
                       * temperature)
        ent_r, _, _ = _fused_stats_padded(rows_f32, scale, c, 8,
                                          block_c, interpret)
    stats = stats.at[ids].set(
        jnp.stack([norm_r[:k], ent_r[:k]], axis=-1))
    rows, xg, stats_rows, stats_all, row_ids, _ = _strip_operands(
        x, stats, ids, n, gram_in_bf16)
    strip = _gram_rows_padded(rows, xg, stats_rows, stats_all, row_ids,
                              lam, 1e-8, _BK, bn, block_c,
                              interpret)[:k, :n]
    dist = dist.at[ids].set(strip)
    dist = dist.at[:, ids].set(strip.T)
    return stats[:, 1], dist, stats


@functools.partial(jax.jit,
                   static_argnames=("metric", "block_n", "block_c",
                                    "gram_in_bf16", "interpret"))
def cached_feature_step_pallas(feats: jnp.ndarray, dist: jnp.ndarray,
                               stats: jnp.ndarray, ids: jnp.ndarray,
                               metric: str = "cosine",
                               block_n: int = 128, block_c: int = 512,
                               gram_in_bf16: bool = False,
                               interpret: bool = True):
    """Incremental FULL-UPDATE distance step (CS/DivFL), kernel path.

    (N, F) flattened-update features + cached (dist (N, N), stats
    (N, 2) = [L2 norm, 0]) + (K,) refreshed ids -> (dist, stats) with
    rows/cols of ``ids`` recomputed through the strip kernel and
    re-symmetrized — O(K·N·F) instead of O(N²·F).  ``metric`` is the
    selector's own distance: "cosine" (Clustered Sampling's angular
    distance) or "l2" (DivFL's Euclidean).  The stats lane layout
    matches the HiCS cache (entropy lane carried as zero) so ONE state
    pytree serves every cached selector.  K = 0 returns the cache
    unchanged; duplicate ids are harmless.
    """
    if metric not in ("cosine", "l2"):
        raise ValueError(f"unknown metric {metric!r}; expected "
                         "'cosine' or 'l2'")
    n, c = feats.shape
    k = ids.shape[0]
    if k == 0:
        return dist, stats
    bn, n_pad, c_pad = _gram_blocks(n, c, block_n, block_c)
    x = jnp.pad(feats.astype(jnp.float32), ((0, n_pad - n),
                                            (0, c_pad - c)))
    rows_f32 = x[ids]                                   # (K, c_pad)
    norms = jnp.sqrt(jnp.sum(rows_f32 * rows_f32, axis=-1))
    stats = stats.at[ids].set(
        jnp.stack([norms, jnp.zeros_like(norms)], axis=-1))
    rows, xg, stats_rows, stats_all, row_ids, _ = _strip_operands(
        x, stats, ids, n, gram_in_bf16)
    strip = _gram_rows_padded(rows, xg, stats_rows, stats_all, row_ids,
                              0.0, 1e-8, _BK, bn, block_c, interpret,
                              epilogue=metric)[:k, :n]
    # the oracle's scatter (transpose-averaged K×K block) keeps the
    # exact-symmetry invariant identical across backends
    return ref._scatter_strip_symmetric(dist, strip, ids), stats
