"""Pallas TPU kernel: single-sweep row stats for the HiCS selection step.

The server-side selection path needs three per-client quantities from
the (N, C) bias-update matrix before the Gram kernel can run:

    entropy  Ĥ = H(softmax(Δb/T))     (Eq. 7 heterogeneity estimate)
    norm     |Δb|₂                     (Gram epilogue denominator)
    rms      sqrt(mean Δb²)            (normalized-estimator scale)

Computed separately (entropy kernel + ``jnp.linalg.norm`` + the pad
copy) that is three HBM sweeps over (N, C) — at LLM-head widths
(C up to 256k) the step is bandwidth-bound, so pass count ≈ wall time.
This kernel fuses all three into ONE streaming pass: the online-softmax
carry of ``hetero_entropy`` extended with a running sum of squares,

    (m, Z, S, Σx²)  per row, updated class-block by class-block,

emitting all three outputs in the last block's epilogue.  An optional
per-row scale multiplies rows before the tempered softmax (norm/RMS are
always of the raw rows) — that hook gives the ``normalize=True``
estimator (``core.hetero.estimate_entropy``) a kernel path: sweep once
for RMS, once more with scale = 1/RMS, instead of no Pallas route at
all.

Grid: (row blocks, class blocks); the class axis is minor/sequential so
the VMEM scratch carries state row-block by row-block, exactly like
``hetero_entropy``.  Rows pad to 8, classes block at 512 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fused_stats_kernel(x_ref, scale_ref, ent_ref, norm_ref, rms_ref,
                        m_ref, z_ref, s_ref, ss_ref, *, c_total, block_c):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        z_ref[...] = jnp.zeros_like(z_ref)
        s_ref[...] = jnp.zeros_like(s_ref)
        ss_ref[...] = jnp.zeros_like(ss_ref)

    x = x_ref[...].astype(jnp.float32)                      # (bn, bc)
    u = x * scale_ref[...]      # scale carries 1/T (and 1/RMS if used)
    # mask the tail of the last class block
    col = ci * block_c + jax.lax.broadcasted_iota(jnp.int32, u.shape, 1)
    valid = col < c_total
    u = jnp.where(valid, u, NEG_INF)

    m_prev = m_ref[...]                                     # (bn, 1)
    m_blk = jnp.max(u, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_blk)
    alpha = jnp.exp(m_prev - m_new)
    e = jnp.where(valid, jnp.exp(u - m_new), 0.0)
    z_blk = jnp.sum(e, axis=-1, keepdims=True)
    s_blk = jnp.sum(e * jnp.where(valid, u - m_new, 0.0), axis=-1,
                    keepdims=True)
    z_prev = z_ref[...]
    s_prev = s_ref[...]
    z_new = z_prev * alpha + z_blk
    s_new = (s_prev + (m_prev - m_new) * z_prev) * alpha + s_blk
    # sum of squares needs no column mask: padded tail entries are zero
    ss_new = ss_ref[...] + jnp.sum(
        jnp.where(valid, x * x, 0.0), axis=-1, keepdims=True)
    m_ref[...] = m_new
    z_ref[...] = z_new
    s_ref[...] = s_new
    ss_ref[...] = ss_new

    @pl.when(ci == nc - 1)
    def _epilogue():
        ent_ref[...] = jnp.log(z_new) - s_new / z_new
        norm_ref[...] = jnp.sqrt(ss_new)
        rms_ref[...] = jnp.sqrt(ss_new / c_total)


def _fused_stats_padded(x: jnp.ndarray, scale_col: jnp.ndarray,
                        c_total: int, block_n: int, block_c: int,
                        interpret: bool):
    """Run the kernel on an already padded/aligned (n_pad, c_pad) buffer.

    Shared by :func:`fused_stats_pallas` (which pads) and the fused
    selection step in ``ops.py`` (which pads ONCE for both this kernel
    and the Gram kernel).  ``scale_col`` (n_pad, 1) carries 1/T — and
    1/RMS on the normalized second pass.  Returns (ent, norm, rms),
    each (n_pad,).
    """
    n_pad, c_pad = x.shape
    grid = (n_pad // block_n, c_pad // block_c)
    ent, norm, rms = pl.pallas_call(
        functools.partial(_fused_stats_kernel,
                          c_total=c_total, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ],
        scratch_shapes=[
            # (m, z, s, Σx²) running stats in VMEM, one lane per row
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
            pltpu.VMEM((block_n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, scale_col)
    return ent[:, 0], norm[:, 0], rms[:, 0]


@functools.partial(jax.jit,
                   static_argnames=("temperature", "block_n", "block_c",
                                    "interpret"))
def fused_stats_pallas(updates: jnp.ndarray, temperature: float,
                       row_scale: jnp.ndarray | None = None,
                       block_n: int = 8, block_c: int = 512,
                       interpret: bool = True):
    """(N, C) -> (entropy, l2 norm, RMS), each (N,) f32, in one sweep.

    ``row_scale`` (N,) optionally multiplies each row before the
    tempered softmax; norm/RMS always describe the raw rows.
    interpret=True on CPU (the TPU is the compile target; this
    container validates in interpret mode).
    """
    n, c = updates.shape
    n_pad = -(-n // block_n) * block_n
    c_pad = -(-c // block_c) * block_c
    x = jnp.pad(updates, ((0, n_pad - n), (0, c_pad - c)))
    # fold the temperature into the per-row scale: u = x·s/T
    scale = (jnp.full((n,), 1.0 / temperature, jnp.float32)
             if row_scale is None
             else row_scale.astype(jnp.float32) / temperature)
    scale_col = jnp.pad(scale, (0, n_pad - n),
                        constant_values=1.0)[:, None]
    ent, norm, rms = _fused_stats_padded(x, scale_col, c, block_n,
                                         block_c, interpret)
    return ent[:n], norm[:n], rms[:n]
