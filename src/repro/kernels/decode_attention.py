"""Pallas TPU kernel: GQA flash-decode (one query token vs. blocked KV).

The serving hot loop for decode_32k / long_500k: one new token attends
to a KV cache of up to 512k positions.  Per (batch, kv-head) the kernel
streams the cache through VMEM in S-blocks with online softmax:

    m' = max(m, max(logits_blk));  l' = l·e^{m−m'} + Σe^{logits−m'}
    o' = o·e^{m−m'} + e^{logits−m'} · V_blk

All G = H/KV query heads of one KV group ride together so each K/V
block is read from HBM exactly once per group (GQA's whole point); the
(G, dh) accumulator and (G, 1) stats stay in VMEM scratch across the
sequence grid axis.  Positions ≥ `length` (ragged cache) are masked.

Grid: (B, KV, S/BS); S minor/sequential.  Block shapes: (G, dh) query
tile, (BS, dh) K/V tiles — dh ∈ {64, 128, 256} are all lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale, block_s):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)         # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)         # (BS, dh)
    v = v_ref[0, 0].astype(jnp.float32)         # (BS, dh)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale       # (G, BS)
    pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = pos < len_ref[0]
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]                          # (G, 1)
    m_blk = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_blk)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(logits - m_new), 0.0)    # (G, BS)
    l_new = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_new = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (G, dh)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(si == ns - 1)
    def _epilogue():
        o_ref[0, 0] = acc_new / jnp.maximum(l_new, 1e-30)


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_s", "interpret"))
def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            length, scale: float | None = None,
                            block_s: int = 512,
                            interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, dh); k/v: (B, S, KV, dh); length: () or (B,) valid len.

    Returns (B, H, dh) f32.  H % KV == 0 (GQA).
    """
    B, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    g = H // KV
    scale = (dh ** -0.5) if scale is None else scale
    lengths = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))

    bs = min(block_s, S)
    s_pad = -(-S // bs) * bs
    kp = jnp.pad(k, ((0, 0), (0, s_pad - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, s_pad - S), (0, 0), (0, 0)))
    # (B, KV, G, dh) query; (B, KV, S, dh) cache — kv-head major for tiling
    qg = q.reshape(B, KV, g, dh)
    kt = kp.transpose(0, 2, 1, 3)
    vt = vp.transpose(0, 2, 1, 3)
    grid = (B, KV, s_pad // bs)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_s=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, dh), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg, kt, vt)
    return out.reshape(B, H, dh)
