"""Public kernel API with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they
run under ``interpret=True`` or fall back to the jnp oracle — both
paths are bit-for-bit validated against ``ref.py`` by the test suite.

    estimate_entropies(updates, T)          (N, C) -> (N,)
    hics_selection_step(updates, T, lam)    (N, C) -> ((N,), (N, N))
    hics_selection_step_cached(...)         K-row incremental refresh
    cached_feature_step(feats, ...)         K-row refresh, cosine/L2
                                            metric (CS / DivFL)
    gram_row_update(updates, stats, ids)    (K, N) distance strip
                                            (arccos / cosine / l2)
    pairwise_distances(updates, T, lam)     (N, C) -> (N, N)   [Eq. 9]
    gqa_decode_attention(q, k, v, length)   one-token flash decode
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.fused_stats import fused_stats_pallas
from repro.kernels.gram_update import (cached_feature_step_pallas,
                                       cached_selection_step_pallas,
                                       gram_row_update_pallas)
from repro.kernels.hetero_entropy import entropy_pallas
from repro.kernels.pairwise import (hics_selection_step_pallas,
                                    pairwise_distance_pallas)
# profiler span labels (exact no-ops unless REPRO_TRACE=1); trace.py is
# a leaf module, so this import closes no cycle with repro.core
from repro.telemetry.trace import annotate


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@annotate("kernels/estimate_entropies")
def estimate_entropies(updates: jnp.ndarray, temperature: float,
                       use_pallas: bool | None = None) -> jnp.ndarray:
    """Ĥ over N clients' bias updates; Pallas on TPU, oracle on CPU."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return entropy_pallas(updates, temperature,
                              interpret=not _on_tpu())
    return ref.entropy_ref(updates, temperature)


@annotate("kernels/fused_row_stats")
def fused_row_stats(updates: jnp.ndarray, temperature: float,
                    use_pallas: bool | None = None):
    """(Ĥ, |Δb|₂, RMS) per client in one HBM sweep over (N, C)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return fused_stats_pallas(updates, temperature,
                                  interpret=not _on_tpu())
    return ref.fused_stats_ref(updates, temperature)


@annotate("kernels/hics_selection_step")
def hics_selection_step(updates: jnp.ndarray, temperature: float,
                        lam: float = 10.0, normalize: bool = False,
                        gram_in_bf16: bool = False,
                        use_pallas: bool | None = None):
    """The entire pre-cluster selection pipeline in one jitted step:

        (N, C) Δb  ->  (Ĥ (N,), Eq. 9 distance (N, N))

    One pad, one pre-Gram sweep (fused entropy+norm+RMS), then the
    Gram/arccos kernel with no host round trip.  ``normalize=True``
    uses the RMS-normalized estimator (one extra stats sweep on the
    kernel path).  Pallas on TPU, jitted oracle on CPU.
    """
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return hics_selection_step_pallas(
            updates, temperature, lam=lam, normalize=normalize,
            gram_in_bf16=gram_in_bf16, interpret=not _on_tpu())
    return _selection_step_ref_jit(updates, temperature, lam, normalize)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _selection_step_ref_jit(updates, temperature, lam, normalize):
    return ref.selection_step_ref(updates, temperature, lam,
                                  normalize=normalize)


@annotate("kernels/hics_selection_step_cached")
def hics_selection_step_cached(updates: jnp.ndarray, dist: jnp.ndarray,
                               stats: jnp.ndarray, ids: jnp.ndarray,
                               temperature: float, lam: float = 10.0,
                               normalize: bool = False,
                               gram_in_bf16: bool = False,
                               use_pallas: bool | None = None):
    """Incremental HiCS selection step (Alg. 1's K-row replacement):

        (N, C) Δb, cached (dist (N, N), stats (N, 2) = [norm, Ĥ]),
        (K,) refreshed ids  ->  (Ĥ (N,), dist, stats)

    Only the rows/cols of ``ids`` are recomputed and re-symmetrized —
    O(K·N·C) per round instead of the full step's O(N²·C).  The caller
    owns the invariant that every row was refreshed since its Δb row
    last changed (the functional hics selector refreshes the previous
    round's participants at the top of every ``select``, which covers
    the strict select→update alternation all drivers use).  Duplicate
    ids are harmless; K = 0 returns the cache unchanged.  Pallas on
    TPU, jitted oracle on CPU — each path reproduces its from-scratch
    counterpart row-for-row.  ``gram_in_bf16`` only affects the kernel
    path (the CPU oracle stays f32, like ``hics_selection_step``).
    """
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return cached_selection_step_pallas(
            updates, dist, stats, ids, temperature, lam=lam,
            normalize=normalize, gram_in_bf16=gram_in_bf16,
            interpret=not _on_tpu())
    return _cached_step_ref_jit(updates, dist, stats, ids, temperature,
                                lam, normalize)


@functools.partial(jax.jit, static_argnums=(4, 5, 6))
def _cached_step_ref_jit(updates, dist, stats, ids, temperature, lam,
                         normalize):
    return ref.cached_selection_step_ref(updates, dist, stats, ids,
                                         temperature, lam,
                                         normalize=normalize)


@annotate("kernels/gram_row_update")
def gram_row_update(updates: jnp.ndarray, stats: jnp.ndarray,
                    ids: jnp.ndarray, lam: float = 10.0,
                    gram_in_bf16: bool = False,
                    epilogue: str = "arccos",
                    use_pallas: bool | None = None) -> jnp.ndarray:
    """(N, C), (N, 2) current [norm, Ĥ], (K,) ids -> (K, N) distance
    strip — the raw K×N Gram product + epilogue behind the cached
    steps, for callers that manage their own scatter.  ``epilogue``
    picks the distance: "arccos" (Eq. 9, HiCS), "cosine" (CS) or "l2"
    (DivFL).  Pallas (MXU tiles, optional bf16 operands / f32
    accumulation) on TPU; jitted lax fallback on CPU."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return gram_row_update_pallas(updates, stats, ids, lam=lam,
                                      gram_in_bf16=gram_in_bf16,
                                      epilogue=epilogue,
                                      interpret=not _on_tpu())
    return _gram_row_update_lax(updates, stats, ids, lam, epilogue)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _gram_row_update_lax(updates, stats, ids, lam, epilogue):
    return ref.distance_strip_ref(updates, stats, ids, lam,
                                  epilogue=epilogue)


@annotate("kernels/cached_feature_step")
def cached_feature_step(feats: jnp.ndarray, dist: jnp.ndarray,
                        stats: jnp.ndarray, ids: jnp.ndarray,
                        metric: str = "cosine",
                        gram_in_bf16: bool = False,
                        use_pallas: bool | None = None):
    """Incremental full-update distance step (the CS/DivFL analogue of
    ``hics_selection_step_cached``):

        (N, F) features, cached (dist (N, N), stats (N, 2) = [norm, 0]),
        (K,) refreshed ids  ->  (dist, stats)

    Only the rows/cols of ``ids`` are recomputed through the strip
    kernel and re-symmetrized — O(K·N·F) per round instead of the
    from-scratch O(N²·F) matrix build.  ``metric`` is the selector's
    own distance ("cosine" for Clustered Sampling, "l2" for DivFL).
    Same caller-owned invariant as the HiCS step: every row must have
    been refreshed since its feature row last changed (the functional
    cs/divfl selectors stale exactly the rows ``update`` writes and
    refresh them at the top of the next ``select``).  Duplicate ids are
    harmless; K = 0 returns the cache unchanged.  Pallas on TPU, jitted
    oracle on CPU.
    """
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return cached_feature_step_pallas(
            feats, dist, stats, ids, metric=metric,
            gram_in_bf16=gram_in_bf16, interpret=not _on_tpu())
    return _cached_feature_step_ref_jit(feats, dist, stats, ids, metric)


@functools.partial(jax.jit, static_argnums=(4,))
def _cached_feature_step_ref_jit(feats, dist, stats, ids, metric):
    return ref.cached_feature_step_ref(feats, dist, stats, ids,
                                       metric=metric)


@annotate("kernels/pairwise_distances")
def pairwise_distances(updates: jnp.ndarray, temperature: float,
                       lam: float = 10.0,
                       use_pallas: bool | None = None) -> jnp.ndarray:
    """Full Eq. 9 matrix: one fused stats sweep + Gram/arccos kernel."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        _, dist = hics_selection_step_pallas(updates, temperature,
                                             lam=lam,
                                             interpret=not _on_tpu())
        return dist
    h = ref.entropy_ref(updates, temperature)
    return ref.pairwise_distance_ref(updates, h, lam)


@annotate("kernels/gqa_decode_attention")
def gqa_decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         length, scale: float | None = None,
                         use_pallas: bool | None = None) -> jnp.ndarray:
    """One-token GQA attention against a (B, S, KV, dh) cache."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return decode_attention_pallas(q, k, v, length, scale=scale,
                                       interpret=not _on_tpu())
    return ref.decode_attention_ref(q, k, v, length, scale=scale)
