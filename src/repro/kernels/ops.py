"""Public kernel API with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they
run under ``interpret=True`` or fall back to the jnp oracle — both
paths are bit-for-bit validated against ``ref.py`` by the test suite.

    estimate_entropies(updates, T)          (N, C) -> (N,)
    hics_selection_step(updates, T, lam)    (N, C) -> ((N,), (N, N))
    hics_selection_step_cached(...)         K-row incremental refresh
    gram_row_update(updates, stats, ids)    (K, N) Eq. 9 distance strip
    pairwise_distances(updates, T, lam)     (N, C) -> (N, N)   [Eq. 9]
    gqa_decode_attention(q, k, v, length)   one-token flash decode
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.fused_stats import fused_stats_pallas
from repro.kernels.gram_update import (cached_selection_step_pallas,
                                       gram_row_update_pallas)
from repro.kernels.hetero_entropy import entropy_pallas
from repro.kernels.pairwise import (hics_selection_step_pallas,
                                    pairwise_distance_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def estimate_entropies(updates: jnp.ndarray, temperature: float,
                       use_pallas: bool | None = None) -> jnp.ndarray:
    """Ĥ over N clients' bias updates; Pallas on TPU, oracle on CPU."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return entropy_pallas(updates, temperature,
                              interpret=not _on_tpu())
    return ref.entropy_ref(updates, temperature)


def fused_row_stats(updates: jnp.ndarray, temperature: float,
                    use_pallas: bool | None = None):
    """(Ĥ, |Δb|₂, RMS) per client in one HBM sweep over (N, C)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return fused_stats_pallas(updates, temperature,
                                  interpret=not _on_tpu())
    return ref.fused_stats_ref(updates, temperature)


def hics_selection_step(updates: jnp.ndarray, temperature: float,
                        lam: float = 10.0, normalize: bool = False,
                        gram_in_bf16: bool = False,
                        use_pallas: bool | None = None):
    """The entire pre-cluster selection pipeline in one jitted step:

        (N, C) Δb  ->  (Ĥ (N,), Eq. 9 distance (N, N))

    One pad, one pre-Gram sweep (fused entropy+norm+RMS), then the
    Gram/arccos kernel with no host round trip.  ``normalize=True``
    uses the RMS-normalized estimator (one extra stats sweep on the
    kernel path).  Pallas on TPU, jitted oracle on CPU.
    """
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return hics_selection_step_pallas(
            updates, temperature, lam=lam, normalize=normalize,
            gram_in_bf16=gram_in_bf16, interpret=not _on_tpu())
    return _selection_step_ref_jit(updates, temperature, lam, normalize)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _selection_step_ref_jit(updates, temperature, lam, normalize):
    return ref.selection_step_ref(updates, temperature, lam,
                                  normalize=normalize)


def hics_selection_step_cached(updates: jnp.ndarray, dist: jnp.ndarray,
                               stats: jnp.ndarray, ids: jnp.ndarray,
                               temperature: float, lam: float = 10.0,
                               normalize: bool = False,
                               gram_in_bf16: bool = False,
                               use_pallas: bool | None = None):
    """Incremental HiCS selection step (Alg. 1's K-row replacement):

        (N, C) Δb, cached (dist (N, N), stats (N, 2) = [norm, Ĥ]),
        (K,) refreshed ids  ->  (Ĥ (N,), dist, stats)

    Only the rows/cols of ``ids`` are recomputed and re-symmetrized —
    O(K·N·C) per round instead of the full step's O(N²·C).  The caller
    owns the invariant that every row was refreshed since its Δb row
    last changed (the functional hics selector refreshes the previous
    round's participants at the top of every ``select``, which covers
    the strict select→update alternation all drivers use).  Duplicate
    ids are harmless; K = 0 returns the cache unchanged.  Pallas on
    TPU, jitted oracle on CPU — each path reproduces its from-scratch
    counterpart row-for-row.  ``gram_in_bf16`` only affects the kernel
    path (the CPU oracle stays f32, like ``hics_selection_step``).
    """
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return cached_selection_step_pallas(
            updates, dist, stats, ids, temperature, lam=lam,
            normalize=normalize, gram_in_bf16=gram_in_bf16,
            interpret=not _on_tpu())
    return _cached_step_ref_jit(updates, dist, stats, ids, temperature,
                                lam, normalize)


@functools.partial(jax.jit, static_argnums=(4, 5, 6))
def _cached_step_ref_jit(updates, dist, stats, ids, temperature, lam,
                         normalize):
    return ref.cached_selection_step_ref(updates, dist, stats, ids,
                                         temperature, lam,
                                         normalize=normalize)


def gram_row_update(updates: jnp.ndarray, stats: jnp.ndarray,
                    ids: jnp.ndarray, lam: float = 10.0,
                    gram_in_bf16: bool = False,
                    use_pallas: bool | None = None) -> jnp.ndarray:
    """(N, C), (N, 2) current [norm, Ĥ], (K,) ids -> (K, N) Eq. 9
    distance strip — the raw K×N Gram/arccos product behind the cached
    step, for callers that manage their own scatter.  Pallas (MXU
    tiles, optional bf16 operands / f32 accumulation) on TPU; jitted
    lax fallback on CPU."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return gram_row_update_pallas(updates, stats, ids, lam=lam,
                                      gram_in_bf16=gram_in_bf16,
                                      interpret=not _on_tpu())
    return _gram_row_update_lax(updates, stats, ids, lam)


@functools.partial(jax.jit, static_argnums=(3,))
def _gram_row_update_lax(updates, stats, ids, lam):
    return ref.distance_strip_ref(updates, stats, ids, lam)


def pairwise_distances(updates: jnp.ndarray, temperature: float,
                       lam: float = 10.0,
                       use_pallas: bool | None = None) -> jnp.ndarray:
    """Full Eq. 9 matrix: one fused stats sweep + Gram/arccos kernel."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        _, dist = hics_selection_step_pallas(updates, temperature,
                                             lam=lam,
                                             interpret=not _on_tpu())
        return dist
    h = ref.entropy_ref(updates, temperature)
    return ref.pairwise_distance_ref(updates, h, lam)


def gqa_decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         length, scale: float | None = None,
                         use_pallas: bool | None = None) -> jnp.ndarray:
    """One-token GQA attention against a (B, S, KV, dh) cache."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return decode_attention_pallas(q, k, v, length, scale=scale,
                                       interpret=not _on_tpu())
    return ref.decode_attention_ref(q, k, v, length, scale=scale)
