"""Public kernel API with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they
run under ``interpret=True`` or fall back to the jnp oracle — both
paths are bit-for-bit validated against ``ref.py`` by the test suite.

    estimate_entropies(updates, T)          (N, C) -> (N,)
    pairwise_distances(updates, T, lam)     (N, C) -> (N, N)   [Eq. 9]
    gqa_decode_attention(q, k, v, length)   one-token flash decode
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.hetero_entropy import entropy_pallas
from repro.kernels.pairwise import pairwise_distance_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def estimate_entropies(updates: jnp.ndarray, temperature: float,
                       use_pallas: bool | None = None) -> jnp.ndarray:
    """Ĥ over N clients' bias updates; Pallas on TPU, oracle on CPU."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return entropy_pallas(updates, temperature,
                              interpret=not _on_tpu())
    return ref.entropy_ref(updates, temperature)


def pairwise_distances(updates: jnp.ndarray, temperature: float,
                       lam: float = 10.0,
                       use_pallas: bool | None = None) -> jnp.ndarray:
    """Full Eq. 9 matrix: entropy pass + fused Gram/arccos kernel."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        interp = not _on_tpu()
        h = entropy_pallas(updates, temperature, interpret=interp)
        norms = jnp.linalg.norm(updates.astype(jnp.float32), axis=-1)
        return pairwise_distance_pallas(updates, norms, h, lam=lam,
                                        interpret=interp)
    h = ref.entropy_ref(updates, temperature)
    return ref.pairwise_distance_ref(updates, h, lam)


def gqa_decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         length, scale: float | None = None,
                         use_pallas: bool | None = None) -> jnp.ndarray:
    """One-token GQA attention against a (B, S, KV, dh) cache."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return decode_attention_pallas(q, k, v, length, scale=scale,
                                       interpret=not _on_tpu())
    return ref.decode_attention_ref(q, k, v, length, scale=scale)
