"""Pallas TPU kernel: Eq. 9 pairwise client distance with fused epilogue.

    D[u, k] = arccos( <Δb_u, Δb_k> / (|Δb_u||Δb_k|) ) + λ |Ĥ_u − Ĥ_k|

Inputs are the (N, C) bias-update matrix (C = classes/vocab, up to
256k) and a per-row stats vector (N, 2) = [L2 norm, Ĥ] — both produced
in ONE streaming pass by ``fused_stats``.  The kernel tiles the Gram
product X Xᵀ for the MXU — (BN, BC) × (BC, BN) partial products
accumulated in a VMEM f32 scratch over the C grid axis — and applies
the normalize→clip→arccos→+λ|ΔĤ| epilogue on the last C block, so the
(N, N) result is written to HBM exactly once and no (N, N) cosine
intermediate ever exists.

``hics_selection_step_pallas`` is the end-to-end fused selection step:
it pads (N, C) ONCE, runs the fused stats sweep, and feeds the outputs
straight into this Gram kernel inside a single jit — no host round
trip, and optionally with the Gram operands cast to bf16 (f32
accumulation stays) for 2× Gram bandwidth.

Grid: (row tiles i, col tiles j, C blocks); C is minor/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_stats import _fused_stats_padded


def _pairwise_kernel(x_ref, xt_ref, stats_ref, statsT_ref,
                     o_ref, acc_ref, *, lam, eps, block_n):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = x_ref[...].astype(jnp.float32)       # (BN, BC) rows tile
    b = xt_ref[...].astype(jnp.float32)      # (BN, BC) cols tile
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _epilogue():
        # stats lanes: [:, 0] = L2 norm, [:, 1] = entropy
        nr = stats_ref[..., 0:1].astype(jnp.float32)      # (BN, 1)
        ncol = statsT_ref[..., 0:1].astype(jnp.float32)   # (BN, 1)
        denom = jnp.maximum(nr, eps) * jnp.maximum(ncol, eps).T
        cos = acc_ref[...] / denom
        cos = jnp.clip(cos, -1.0 + 1e-7, 1.0 - 1e-7)
        ang = jnp.arccos(cos)
        # zero the true diagonal (only on diagonal tiles)
        row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, ang.shape, 0)
        col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, ang.shape, 1)
        ang = jnp.where(row == col, 0.0, ang)
        hr = stats_ref[..., 1:2].astype(jnp.float32)      # (BN, 1)
        hc = statsT_ref[..., 1:2].astype(jnp.float32)     # (BN, 1)
        o_ref[...] = ang + lam * jnp.abs(hr - hc.T)


def _pairwise_padded(x: jnp.ndarray, stats: jnp.ndarray, lam: float,
                     eps: float, bn: int, block_c: int,
                     interpret: bool) -> jnp.ndarray:
    """Gram/arccos kernel on an already padded (n_pad, c_pad) buffer.

    ``stats`` is (n_pad, 2) = [norm, entropy]; padded rows must carry a
    nonzero norm.  The same buffer feeds the row and column tiles (two
    operand slots, one HBM allocation — no copy is made).
    """
    n_pad = x.shape[0]
    c_pad = x.shape[1]
    grid = (n_pad // bn, n_pad // bn, c_pad // block_c)
    return pl.pallas_call(
        functools.partial(_pairwise_kernel, lam=lam, eps=eps,
                          block_n=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, block_c), lambda i, j, k: (i, k)),  # rows
            pl.BlockSpec((bn, block_c), lambda i, j, k: (j, k)),  # cols
            pl.BlockSpec((bn, 2), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        interpret=interpret,
    )(x, x, stats, stats)


def _gram_blocks(n: int, c: int, block_n: int, block_c: int):
    """Padded sizes aligned for the Gram tiling: (bn, n_pad, c_pad)."""
    bn = min(block_n, max(8, -(-n // 8) * 8))
    return bn, -(-n // bn) * bn, -(-c // block_c) * block_c


@functools.partial(jax.jit,
                   static_argnames=("lam", "block_n", "block_c",
                                    "gram_in_bf16", "interpret"))
def pairwise_distance_pallas(updates: jnp.ndarray, norms: jnp.ndarray,
                             entropies: jnp.ndarray, lam: float = 10.0,
                             block_n: int = 128, block_c: int = 512,
                             gram_in_bf16: bool = False,
                             interpret: bool = True) -> jnp.ndarray:
    """(N, C), (N,), (N,) -> (N, N) Eq. 9 distances (f32)."""
    n, c = updates.shape
    bn, n_pad, c_pad = _gram_blocks(n, c, block_n, block_c)
    x = jnp.pad(updates, ((0, n_pad - n), (0, c_pad - c)))
    if gram_in_bf16:
        x = x.astype(jnp.bfloat16)
    # pad norms with 1s so padded rows don't divide by 0
    nr = jnp.pad(norms.astype(jnp.float32), (0, n_pad - n),
                 constant_values=1.0)
    h = jnp.pad(entropies.astype(jnp.float32), (0, n_pad - n))
    stats = jnp.stack([nr, h], axis=-1)                  # (n_pad, 2)
    out = _pairwise_padded(x, stats, lam, 1e-8, bn, block_c, interpret)
    return out[:n, :n]


@functools.partial(jax.jit,
                   static_argnames=("temperature", "lam", "normalize",
                                    "block_n", "block_c", "gram_in_bf16",
                                    "interpret"))
def hics_selection_step_pallas(updates: jnp.ndarray, temperature: float,
                               lam: float = 10.0, normalize: bool = False,
                               block_n: int = 128, block_c: int = 512,
                               gram_in_bf16: bool = False,
                               interpret: bool = True):
    """Fused HiCS selection step: (N, C) -> (Ĥ (N,), Eq. 9 D (N, N)).

    One pad, one pre-Gram HBM sweep (the fused stats kernel), then the
    Gram/arccos kernel on the same padded buffer — all inside one jit.
    ``normalize=True`` adds a second stats sweep with rows scaled by
    1/RMS (the magnitude-invariant estimator); the unfused baseline had
    no kernel path for it at all.  ``gram_in_bf16`` halves Gram operand
    bandwidth while keeping f32 accumulation.
    """
    n, c = updates.shape
    bn, n_pad, c_pad = _gram_blocks(n, c, block_n, block_c)
    x = jnp.pad(updates, ((0, n_pad - n), (0, c_pad - c)))
    inv_t = jnp.full((n_pad, 1), 1.0 / temperature, jnp.float32)
    ent, norm, rms = _fused_stats_padded(x, inv_t, c, 8, block_c,
                                         interpret)
    if normalize:
        scale = 1.0 / (jnp.clip(rms, 1e-12, None)[:, None] * temperature)
        ent, _, _ = _fused_stats_padded(x, scale, c, 8, block_c,
                                        interpret)
    # padded rows have zero norm; give them 1 so the epilogue never
    # divides by eps² (their rows/cols are sliced away below)
    live = jnp.arange(n_pad) < n
    stats = jnp.stack([jnp.where(live, norm, 1.0), ent], axis=-1)
    xg = x.astype(jnp.bfloat16) if gram_in_bf16 else x
    dist = _pairwise_padded(xg, stats, lam, 1e-8, bn, block_c, interpret)
    return ent[:n], dist[:n, :n]
