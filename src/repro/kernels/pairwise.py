"""Pallas TPU kernel: Eq. 9 pairwise client distance with fused epilogue.

    D[u, k] = arccos( <Δb_u, Δb_k> / (|Δb_u||Δb_k|) ) + λ |Ĥ_u − Ĥ_k|

Inputs are the (N, C) bias-update matrix (C = classes/vocab, up to
256k), the per-row L2 norms (N,) and the estimated entropies (N,)
(both O(N·C) streaming passes produced by ``ops.py``).  The kernel
tiles the Gram product X Xᵀ for the MXU — (BN, BC) × (BC, BN) partial
products accumulated in a VMEM f32 scratch over the C grid axis — and
applies the normalize→clip→arccos→+λ|ΔĤ| epilogue on the last C block,
so the (N, N) result is written to HBM exactly once and no (N, N)
cosine intermediate ever exists.

Grid: (row tiles i, col tiles j, C blocks); C is minor/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pairwise_kernel(x_ref, xt_ref, norms_ref, normsT_ref, h_ref, hT_ref,
                     o_ref, acc_ref, *, lam, eps, n_total, block_n):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = x_ref[...].astype(jnp.float32)       # (BN, BC) rows tile
    b = xt_ref[...].astype(jnp.float32)      # (BN, BC) cols tile
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _epilogue():
        nr = norms_ref[...].astype(jnp.float32)      # (BN, 1)
        ncol = normsT_ref[...].astype(jnp.float32)   # (BN, 1)
        denom = jnp.maximum(nr, eps) * jnp.maximum(ncol, eps).T
        cos = acc_ref[...] / denom
        cos = jnp.clip(cos, -1.0 + 1e-7, 1.0 - 1e-7)
        ang = jnp.arccos(cos)
        # zero the true diagonal (only on diagonal tiles)
        row = i * block_n + jax.lax.broadcasted_iota(jnp.int32, ang.shape, 0)
        col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, ang.shape, 1)
        ang = jnp.where(row == col, 0.0, ang)
        hr = h_ref[...].astype(jnp.float32)          # (BN, 1)
        hc = hT_ref[...].astype(jnp.float32)         # (BN, 1)
        o_ref[...] = ang + lam * jnp.abs(hr - hc.T)


@functools.partial(jax.jit,
                   static_argnames=("lam", "block_n", "block_c",
                                    "interpret"))
def pairwise_distance_pallas(updates: jnp.ndarray, norms: jnp.ndarray,
                             entropies: jnp.ndarray, lam: float = 10.0,
                             block_n: int = 128, block_c: int = 512,
                             interpret: bool = True) -> jnp.ndarray:
    """(N, C), (N,), (N,) -> (N, N) Eq. 9 distances (f32)."""
    n, c = updates.shape
    bn = min(block_n, max(8, -(-n // 8) * 8))
    n_pad = -(-n // bn) * bn
    c_pad = -(-c // block_c) * block_c
    x = jnp.pad(updates, ((0, n_pad - n), (0, c_pad - c)))
    # pad norms with 1s so padded rows don't divide by 0
    nr = jnp.pad(norms.astype(jnp.float32), (0, n_pad - n),
                 constant_values=1.0)[:, None]
    h = jnp.pad(entropies.astype(jnp.float32), (0, n_pad - n))[:, None]
    grid = (n_pad // bn, n_pad // bn, c_pad // block_c)
    out = pl.pallas_call(
        functools.partial(_pairwise_kernel, lam=lam, eps=1e-8,
                          n_total=n, block_n=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, block_c), lambda i, j, k: (i, k)),  # rows
            pl.BlockSpec((bn, block_c), lambda i, j, k: (j, k)),  # cols
            pl.BlockSpec((bn, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        interpret=interpret,
    )(x, x, nr, nr, h, h)
    return out[:n, :n]
