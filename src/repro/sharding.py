"""Sharding policy: logical-axis rules mapping params/activations onto the
production mesh (MaxText-style, but path-regex driven so the rules live in
one place).

* Params: FSDP over the data-parallel axes + tensor-parallel over 'model',
  chosen per-leaf by ordered path rules with automatic divisibility
  fallback (e.g. the seamless 256,206 vocab cannot shard 16-way and falls
  back to replicated on that dim).
* Activations: models call :func:`constrain` with logical names; outside a
  policy context (unit tests, single device) it is a no-op.
* Attention: heads shard over 'model' when divisible (Megatron), otherwise
  the *query-sequence* axis shards over 'model' (sequence parallelism) —
  needed by deepseek-coder-33b (56 heads) and mixtral-8x22b (48 heads).
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------


class ShardingPolicy:
    """Maps logical axis names -> mesh axis names for one mesh.

    ``mode`` selects the parallelism scheme (found via perf iteration
    on the ``launch.dryrun`` grid):
      "2d"   — FSDP over (pod, data) × tensor-parallel over 'model'
               (the baseline; activations pay per-layer TP collectives)
      "fsdp" — every mesh axis is a data/FSDP axis; params are fully
               sharded and all-gathered layer-by-layer, activations
               never cross chips.  For train_4k-style shapes with
               token-rich per-chip batches this cuts the collective
               roofline term by >10x on dense archs.
    """

    def __init__(self, mesh: Mesh, mode: str = "2d"):
        if mode not in ("2d", "fsdp", "ep"):
            raise ValueError(f"unknown sharding mode {mode!r}")
        self.mesh = mesh
        self.mode = mode
        names = mesh.axis_names
        if mode == "fsdp":
            self.dp_axes = tuple(a for a in ("pod", "data", "model")
                                 if a in names)
            self.tp_axis = None
        elif mode == "ep":
            # expert parallelism: 'pod' hosts the expert dim (E=8 % 2 == 0
            # on the 2x16x16 mesh); batch over 'data', ff over 'model'
            self.dp_axes = ("data",) if "data" in names else ()
            self.tp_axis = "model" if "model" in names else None
            self.ep_axis = "pod" if "pod" in names else None
        else:
            self.dp_axes: Tuple[str, ...] = tuple(
                a for a in ("pod", "data") if a in names)
            self.tp_axis: Optional[str] = ("model" if "model" in names
                                           else None)
        self.ep_axis = getattr(self, "ep_axis", None)
        self.logical = {
            "expert": self.ep_axis,
            "batch": self.dp_axes or None,
            "fsdp": self.dp_axes or None,
            "tp": self.tp_axis,
            "ff": self.tp_axis,
            "heads": self.tp_axis,
            "vocab": self.tp_axis,
            "qseq": self.tp_axis,       # sequence parallelism (attention)
            "kvseq": self.dp_axes or None,  # long-context cache sharding
            "seq": None,
            "embed": None,
        }

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def resolve(self, dim: int, logical_name) -> Optional[object]:
        """Mesh axes for one dim, or None when not divisible/unmapped."""
        if logical_name is None:
            return None
        axes = self.logical.get(logical_name)
        if axes is None:
            return None
        if dim % self.axis_size(axes) != 0:
            return None
        return axes

    def spec(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(logical), (shape, logical)
        return P(*[self.resolve(d, n) for d, n in zip(shape, logical)])


_CURRENT: Optional[ShardingPolicy] = None


@contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    global _CURRENT
    prev, _CURRENT = _CURRENT, policy
    try:
        yield policy
    finally:
        _CURRENT = prev


def current_policy() -> Optional[ShardingPolicy]:
    return _CURRENT


def constrain(x, *logical):
    """Apply with_sharding_constraint by logical names; no-op w/o policy."""
    pol = _CURRENT
    if pol is None:
        return x
    spec = pol.spec(x.shape, logical)
    return lax.with_sharding_constraint(x, NamedSharding(pol.mesh, spec))


def constrain_attn_q(q):
    """q: (B, T, H, dh). Megatron head sharding if divisible, else query-
    sequence parallelism over the model axis."""
    pol = _CURRENT
    if pol is None:
        return q
    B, T, H, dh = q.shape
    tp = pol.tp_axis
    if tp is not None and H % pol.axis_size(tp) == 0:
        return constrain(q, "batch", "seq", "heads", None)
    if tp is not None and T % pol.axis_size(tp) == 0 and T > 1:
        return constrain(q, "batch", "qseq", None, None)
    return constrain(q, "batch", "seq", None, None)


# ---------------------------------------------------------------------------
# Param partition rules (ordered; first match wins)
# ---------------------------------------------------------------------------

# Each rule: (path_regex, logical names for the TRAILING dims). Leading
# (stacked-layer) dims get None. "fsdp" -> dp axes, "tp" -> model axis.
_PARAM_RULES = [
    (r"embed$",            ("fsdp", "tp")),       # (V, d)
    (r"lm_head/w$",        ("fsdp", "tp")),       # (d, V): V -> model
    (r"lm_head/b$",        ("tp",)),              # (V,)
    (r"projector/w$",      ("fsdp", "tp")),
    (r"(wq|wk|wv|wi0|wi1|in_proj|w1|key|receptance|value_ff|gate)$",
                           ("fsdp", "tp")),
    (r"(wo|out_proj|w2|value_out)$", ("tp", "fsdp")),
    (r"router$",           ("fsdp", None)),
    # Expert weights: in "2d" mode shard only over 'model' (ff) — putting
    # d on the batch ('data') axes makes GSPMD reshard the (B, E, C, d)
    # dispatch buffers between batch- and d-sharded layouts every layer
    # (§Perf iteration 4).  In "fsdp" mode there is no tp axis and the
    # experts must not replicate (mixtral: 141B params), so d shards over
    # the fsdp axes instead — see _MOE_FSDP_RULES.
    (r"moe/wi[01]$",       (None, None, "tp")),   # (E, d, ff)
    (r"moe/wo$",           (None, "tp", None)),   # (E, ff, d)
    (r"conv_w$",           ("tp", None)),          # (conv_dim, width)
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


_MOE_FSDP_RULES = [
    (r"moe/wi[01]$",       (None, "fsdp", None)),  # (E, d, ff)
    (r"moe/wo$",           (None, None, "fsdp")),  # (E, ff, d)
]

_MOE_EP_RULES = [
    (r"moe/wi[01]$",       ("expert", None, "tp")),  # (E, d, ff)
    (r"moe/wo$",           ("expert", "tp", None)),  # (E, ff, d)
]


def _leaf_logical(path: str, ndim: int,
                  mode: str = "2d") -> Tuple[Optional[str], ...]:
    if mode == "fsdp":
        rules = _MOE_FSDP_RULES + _PARAM_RULES
    elif mode == "ep":
        rules = _MOE_EP_RULES + _PARAM_RULES
    else:
        rules = _PARAM_RULES
    for pat, trailing in rules:
        if re.search(pat, path):
            t = tuple(trailing)
            if len(t) > ndim:
                t = t[-ndim:]
            return (None,) * (ndim - len(t)) + t
    if ndim >= 2:   # generic fallback: FSDP x TP on the last two dims
        return (None,) * (ndim - 2) + ("fsdp", "tp")
    return (None,) * ndim


def param_pspecs(params, policy: ShardingPolicy):
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs)."""
    def leaf_spec(path, leaf):
        logical = _leaf_logical(_path_str(path), leaf.ndim,
                                getattr(policy, "mode", "2d"))
        return policy.spec(leaf.shape, logical)
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(params, policy: ShardingPolicy):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(policy.mesh, s), param_pspecs(params, policy))


# ---------------------------------------------------------------------------
# Batch / cache partition specs (dry-run + drivers)
# ---------------------------------------------------------------------------


def batch_pspecs(batch, policy: ShardingPolicy):
    """Inputs: leading dim is global batch (dp-sharded when divisible)."""
    def leaf_spec(leaf):
        if leaf.ndim == 0:
            return P()
        dp = policy.resolve(leaf.shape[0], "batch")
        return P(dp, *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map(leaf_spec, batch)


def _kv_cache_spec(shape, policy: ShardingPolicy) -> P:
    """(L, B, S, KV, dh): batch->dp, heads->tp, with fallbacks onto S."""
    _, B, S, KV, _ = shape
    dp = policy.resolve(B, "batch")
    s_axes = []
    if dp is None and policy.dp_axes:
        s_axes.extend(policy.dp_axes)
    tp = policy.tp_axis
    kv_ax = None
    if tp is not None:
        if KV % policy.axis_size(tp) == 0:
            kv_ax = tp
        else:
            s_axes.append(tp)
    s_ax = tuple(s_axes) or None
    if s_ax is not None and S % policy.axis_size(s_ax) != 0:
        s_ax = None
    return P(None, dp, s_ax, kv_ax, None)


def cache_pspecs(cache, policy: ShardingPolicy):
    """Decode-cache pytree specs (KV caches, SSM states, conv states)."""
    def leaf_spec(path, leaf):
        name = _path_str(path).split("/")[-1]
        shp = leaf.shape
        if name in ("k", "v", "xk", "xv") and leaf.ndim == 5:
            return _kv_cache_spec(shp, policy)
        if name == "state" and leaf.ndim == 5:      # (L, B, H, *, *)
            dp = policy.resolve(shp[1], "batch")
            tp = policy.resolve(shp[2], "heads")
            return P(None, dp, tp, None, None)
        if name == "conv" and leaf.ndim == 4:       # (L, B, W-1, C)
            dp = policy.resolve(shp[1], "batch")
            tp = policy.resolve(shp[3], "ff")
            return P(None, dp, None, tp)
        if leaf.ndim >= 2:                          # e.g. xp_att (L, B, d)
            dp = policy.resolve(shp[1], "batch") if leaf.ndim >= 3 else None
            return P(None, dp, *([None] * (leaf.ndim - 2)))
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def to_shardings(pspecs, policy: ShardingPolicy):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(policy.mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
