from repro.optim.optimizers import (
    Optimizer,
    adam,
    apply_updates,
    clip_by_global_norm,
    sgd,
    sgd_momentum,
)

__all__ = ["Optimizer", "adam", "apply_updates", "clip_by_global_norm",
           "sgd", "sgd_momentum"]
