"""Pure-pytree optimizers (no optax in this environment).

API mirrors the usual (init, update) pair:

  opt = adam(1e-3)
  state = opt.init(params)
  updates, state = opt.update(grads, state, params)
  params = tree_map(lambda p, u: p + u, params, updates)

All optimizer state mirrors the parameter sharding (ZeRO-3 on the mesh) —
the dry-run passes opt state through the same `param_pspecs` rules.
The paper's experiments use SGD (FMNIST) and Adam (CIFAR10/Mini-ImageNet/
THUC); App. A.9 analyzes both plus SGD-momentum — we provide all three.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]


def _tree_zeros_like(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None, lr_scale=1.0):
        del params
        upd = jax.tree_util.tree_map(lambda g: -lr * lr_scale * g, grads)
        return upd, {"count": state["count"] + 1}

    return Optimizer(init, update)


def sgd_momentum(lr: float, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_like(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None, lr_scale=1.0):
        del params
        m = jax.tree_util.tree_map(
            lambda mm, g: momentum * mm + (1.0 - momentum) * g,
            state["m"], grads)
        upd = jax.tree_util.tree_map(lambda mm: -lr * lr_scale * mm, m)
        return upd, {"m": m, "count": state["count"] + 1}

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_like(params),
                "v": _tree_zeros_like(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None, lr_scale=1.0):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g),
            state["v"], grads)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c

        def u(mm, vv, p):
            step = mm / bc1 / (jnp.sqrt(vv / bc2) + eps)
            if weight_decay and p is not None:
                step = step + weight_decay * p
            return -lr * lr_scale * step

        if params is None:
            upd = jax.tree_util.tree_map(lambda mm, vv: u(mm, vv, None), m, v)
        else:
            upd = jax.tree_util.tree_map(u, m, v, params)
        return upd, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)
